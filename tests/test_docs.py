"""The documentation suite must not drift from the code: links resolve,
documented CLI flags match the argparse definitions, and every module path
/ symbol named in docs/ALGORITHM.md exists (the CI `docs` job runs the
same checker)."""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_docs_suite_exists():
    assert (REPO / "README.md").exists()
    assert (REPO / "docs" / "ALGORITHM.md").exists()
    assert (REPO / "src" / "repro" / "cache" / "README.md").exists()


def test_check_docs_passes():
    out = subprocess.run([sys.executable, str(REPO / "tools" /
                                              "check_docs.py")],
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"


def test_check_docs_catches_drift(tmp_path, monkeypatch):
    """The checker is not a rubber stamp: a stale documented flag and a
    broken link are both detected."""
    tools_dir = str(REPO / "tools")
    sys.path.insert(0, tools_dir)
    try:
        import check_docs
    finally:
        sys.path.remove(tools_dir)
    # stale flag: README paragraph naming repro.cache.sweep with a bogus flag
    doc = tmp_path / "README.md"
    doc.write_text("run `python -m repro.cache.sweep --no-such-flag` "
                   "and see [missing](does/not/exist.md)\n")
    monkeypatch.setattr(check_docs, "REPO", tmp_path)
    monkeypatch.setattr(check_docs, "LINK_DOCS", ["README.md"])
    monkeypatch.setattr(check_docs, "FLAG_DOCS", ["README.md"])
    assert any("broken link" in e for e in check_docs.check_links())
    flag_errors = check_docs.check_flags()
    assert any("--no-such-flag" in e for e in flag_errors)


def test_check_docs_catches_stale_bench_table(tmp_path, monkeypatch):
    """Doc-embedded BENCH perf tables must match a fresh render from the
    committed scoreboard; --fix rewrites them in place."""
    import json
    tools_dir = str(REPO / "tools")
    sys.path.insert(0, tools_dir)
    try:
        import check_docs
    finally:
        sys.path.remove(tools_dir)
    bench = json.loads((REPO / "BENCH_schedules.json").read_text())
    (tmp_path / "BENCH_schedules.json").write_text(json.dumps(bench))
    readme = tmp_path / "README.md"
    readme.write_text("perf:\n\n<!-- BENCH_TABLE:compile -->\n"
                      "| stale | numbers |\n<!-- /BENCH_TABLE -->\n")
    (tmp_path / "src/repro/cache").mkdir(parents=True)
    (tmp_path / "src/repro/cache/README.md").write_text("no tables here\n")
    monkeypatch.setattr(check_docs, "REPO", tmp_path)
    errors = check_docs.check_bench_numbers()
    assert any("BENCH_TABLE:compile is stale" in e for e in errors)
    # --fix rewrites the block from the scoreboard, after which it's clean
    assert check_docs.check_bench_numbers(fix=True) == []
    assert check_docs.check_bench_numbers() == []
    assert "| stale |" not in readme.read_text()
    expected = check_docs.render_bench_table("compile", bench)
    assert expected in readme.read_text()
