"""The `Collectives` facade: byte-equivalence with the low-level compilers,
cache-first behaviour, option merging, lowering, and the deprecation shims
(the ONLY tests allowed to trigger `ReproDeprecationWarning` — tier-1
promotes it to an error everywhere else)."""
import dataclasses

import pytest

from repro.api import (Collectives, CompileOptions, KINDS,
                       ReproDeprecationWarning)
from repro.cache import ScheduleCache
from repro.cache.serialize import allreduce_to_json, schedule_to_json
from repro.core import (compile_allgather, compile_allreduce,
                        compile_broadcast, compile_reduce,
                        compile_reduce_scatter)
from repro.topo import bidir_ring, fig1a, torus_2d


def art_bytes(art):
    from repro.core.schedule import AllReduceSchedule
    return (allreduce_to_json(art) if isinstance(art, AllReduceSchedule)
            else schedule_to_json(art))


# ---------------------------------------------------------------------- #
# CompileOptions
# ---------------------------------------------------------------------- #

def test_compile_options_validation():
    with pytest.raises(ValueError):
        CompileOptions(kind="gatherscatter")
    with pytest.raises(ValueError):
        CompileOptions(kind="broadcast", fixed_k=2)
    o = CompileOptions(kind="allgather", num_chunks=16)
    assert o.replace(num_chunks=4).num_chunks == 4
    assert o.replace(num_chunks=4) is not o
    assert o.resolved_root(fig1a()) is None
    assert CompileOptions(kind="broadcast").resolved_root(fig1a()) == 0
    assert CompileOptions(kind="reduce", root=3).resolved_root(fig1a()) == 3


def test_facade_defaults_and_overrides():
    coll = Collectives(num_chunks=4, kind="reduce_scatter")
    assert coll.opts().num_chunks == 4
    assert coll.opts(num_chunks=8).num_chunks == 8
    assert coll.opts().kind == "reduce_scatter"
    with pytest.raises(TypeError):
        Collectives(options=CompileOptions(), num_chunks=4)


# ---------------------------------------------------------------------- #
# schedule/family equivalence with the low-level compilers
# ---------------------------------------------------------------------- #

def test_schedule_matches_low_level_compilers():
    g = fig1a()
    coll = Collectives(num_chunks=8)
    pairs = [
        ("allgather", compile_allgather(g, num_chunks=8)),
        ("reduce_scatter", compile_reduce_scatter(g, num_chunks=8)),
        ("broadcast", compile_broadcast(g, root=0, num_chunks=8)),
        ("reduce", compile_reduce(g, root=0, num_chunks=8)),
        ("allreduce", compile_allreduce(g, num_chunks=8)),
    ]
    for kind, want in pairs:
        got = coll.schedule(g, kind=kind)
        assert art_bytes(got) == art_bytes(want), kind


def test_schedule_accepts_spec_strings_and_zoo_names():
    coll = Collectives(num_chunks=4)
    a = coll.schedule("torus4x4")
    b = coll.schedule("torus2d:4x4")
    c = coll.schedule(torus_2d(4, 4))
    assert art_bytes(a) == art_bytes(b) == art_bytes(c)


def test_family_and_pair():
    g = bidir_ring(6)
    coll = Collectives(num_chunks=4)
    fam = coll.family(g, kinds=("allgather", "reduce_scatter", "allreduce"))
    assert set(fam) == {"allgather", "reduce_scatter", "allreduce"}
    assert art_bytes(fam["allgather"]) == \
        art_bytes(compile_allgather(g, num_chunks=4))
    ag, rs = coll.pair(g)
    assert ag.kind == "allgather" and rs.kind == "reduce_scatter"
    timings = {}
    coll.family(g, kinds=("allgather",), timings=timings)
    assert "allgather" in timings


# ---------------------------------------------------------------------- #
# cache behaviour
# ---------------------------------------------------------------------- #

def test_cache_path_hits_skip_compiler(tmp_path, monkeypatch):
    coll = Collectives(cache=str(tmp_path), num_chunks=4)
    assert isinstance(coll.cache, ScheduleCache)
    first = coll.schedule("bring:6")
    monkeypatch.setattr("repro.core.schedule.compile_allgather",
                        lambda *a, **kw: pytest.fail("compiler on hit path"))
    again = Collectives(cache=str(tmp_path), num_chunks=4).schedule("bring:6")
    assert art_bytes(again) == art_bytes(first)


def test_cache_instance_passthrough_and_verify_inheritance(tmp_path):
    cache = ScheduleCache(tmp_path)
    coll = Collectives(cache=cache)
    assert coll.cache is cache
    assert Collectives(cache=str(tmp_path),
                       verify=True).cache.verify_on_compile
    assert Collectives(cache=None).cache is None
    assert Collectives(cache="").cache is None


# ---------------------------------------------------------------------- #
# lowering / programs / executables
# ---------------------------------------------------------------------- #

def test_program_kinds():
    coll = Collectives(num_chunks=4)
    prog = coll.program("bring:6", kind="allgather")
    assert prog.kind == "allgather"
    rs_p, ag_p = coll.program("bring:6", kind="allreduce")
    assert rs_p.kind == "reduce_scatter" and ag_p.kind == "allgather"
    bc = coll.program("star:4", kind="broadcast", root=2)
    assert bc.kind == "broadcast" and bc.root == 2


def test_executable_binds_tree_collectives():
    coll = Collectives(num_chunks=4)
    fn = coll.executable("bring:4", kind="allreduce", axis_name="x")
    assert callable(fn)
    fn2 = coll.executable("bring:4", kind="allgather", axis_name="x")
    assert callable(fn2) and fn2 is not fn


# ---------------------------------------------------------------------- #
# CollectiveContext on top of the facade
# ---------------------------------------------------------------------- #

def test_collective_context_spec_overrides():
    from repro.comms import CollectiveContext
    ctx = CollectiveContext({"data": 4}, num_chunks=4,
                            topologies={"data": "bring:4"})
    ax = ctx.axis("data")
    assert ax.topology.name == "bring4"
    assert ax.ag_prog.axis_size == 4


def test_collective_context_rejects_conflicting_knobs(tmp_path):
    from repro.comms import CollectiveContext
    coll = Collectives(num_chunks=8)
    with pytest.raises(TypeError):
        CollectiveContext({"data": 4}, num_chunks=32, collectives=coll)
    with pytest.raises(TypeError):
        CollectiveContext({"data": 4}, fixed_k=1, collectives=coll)


def test_cache_miss_honors_per_call_verify(tmp_path, monkeypatch):
    import repro.core.schedule as schedule_mod
    seen = {}
    real = schedule_mod.compile_allgather

    def spy(*a, **kw):
        seen["verify"] = kw.get("verify")
        return real(*a, **kw)

    monkeypatch.setattr("repro.core.schedule.compile_allgather", spy)
    coll = Collectives(cache=str(tmp_path), num_chunks=4)
    assert not coll.cache.verify_on_compile
    coll.schedule("bring:6", verify=True)     # miss path must verify
    assert seen["verify"] is True
    assert not coll.cache.verify_on_compile   # flag restored


def test_collective_context_shares_facade(tmp_path):
    from repro.comms import CollectiveContext
    coll = Collectives(cache=str(tmp_path), num_chunks=4)
    ctx = CollectiveContext({"data": 4}, collectives=coll)
    assert ctx.schedule_cache is coll.cache
    assert ctx.num_chunks == 4
    ctx.axis("data")
    assert coll.cache.stats.puts >= 2   # AG + RS artifacts persisted
    with pytest.raises(TypeError):
        CollectiveContext({"data": 4}, collectives=coll,
                          schedule_cache=ScheduleCache(tmp_path))


# ---------------------------------------------------------------------- #
# deprecation shims — pinned here, errors everywhere else
# ---------------------------------------------------------------------- #

def test_schedules_for_topology_shim_warns_and_matches_facade():
    from repro.comms import schedules_for_topology
    g = bidir_ring(6)
    with pytest.warns(ReproDeprecationWarning):
        ag, rs = schedules_for_topology(g, num_chunks=4)
    want_ag, want_rs = Collectives(num_chunks=4).pair(g)
    assert art_bytes(ag) == art_bytes(want_ag)
    assert art_bytes(rs) == art_bytes(want_rs)
    with pytest.warns(ReproDeprecationWarning):
        ar = schedules_for_topology(g, num_chunks=4, kind="allreduce")
    assert art_bytes(ar) == art_bytes(
        Collectives(num_chunks=4).schedule(g, kind="allreduce"))
    with pytest.warns(ReproDeprecationWarning):
        with pytest.raises(ValueError):
            schedules_for_topology(g, num_chunks=4, kind="broadcast")
    with pytest.warns(ReproDeprecationWarning):
        with pytest.raises(ValueError):
            schedules_for_topology(g, num_chunks=4, kind="alltoall")


def test_programs_for_topology_shim_warns_and_matches_facade():
    from repro.comms import programs_for_topology
    g = bidir_ring(6)
    with pytest.warns(ReproDeprecationWarning):
        rs_p, ag_p = programs_for_topology(g, num_chunks=4)
    assert rs_p.kind == "reduce_scatter" and ag_p.kind == "allgather"


def test_deprecation_gate_is_configured():
    """tier-1 must promote ReproDeprecationWarning to an error: the
    pyproject filterwarnings entry is the CI deprecation gate."""
    from pathlib import Path
    text = (Path(__file__).resolve().parent.parent
            / "pyproject.toml").read_text()
    assert "error::repro.api.ReproDeprecationWarning" in text


def test_kinds_constant_matches_cache_sweep():
    from repro.cache import COLLECTIVES
    assert tuple(KINDS) == tuple(COLLECTIVES)
