"""Shared fixtures.  NOTE: no global XLA_FLAGS here — smoke tests must see
one device; multi-device collective tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
