"""Schedule artifact subsystem: fingerprints, exact JSON round-trip,
on-disk cache (hit path must skip the compiler), golden-schedule
regressions, and the topology-zoo sweep."""
import json
from fractions import Fraction
from pathlib import Path

import pytest

from repro.cache import (ALLTOALL_CHUNKS, COLLECTIVES, ScheduleCache,
                         SMOKE_NAMES,
                         allreduce_from_json, allreduce_to_json,
                         compiler_fingerprint, run_sweep, schedule_from_json,
                         schedule_to_json, sweep_one, sweep_registry)
from repro.cache.serialize import ensure_claimed
from repro.core import (compile_allgather, compile_allreduce,
                        compile_alltoall, compile_broadcast, compile_reduce,
                        compile_reduce_scatter, simulate_allgather,
                        simulate_allreduce, simulate_alltoall,
                        simulate_broadcast, simulate_reduce,
                        simulate_reduce_scatter)
from repro.core.graph import DiGraph
from repro.topo import (bcube, bidir_ring, dragonfly, fig1a, hypercube,
                        mesh_of_dgx, ring, two_cluster_switch)

GOLDEN_DIR = Path(__file__).parent / "golden"


# ---------------------------------------------------------------------- #
# graph fingerprint
# ---------------------------------------------------------------------- #

def test_fingerprint_ignores_name_and_insertion_order():
    a = bidir_ring(6, name="a")
    b = bidir_ring(6, name="completely-different")
    assert a.fingerprint() == b.fingerprint()
    # same edges inserted in reverse order
    c = DiGraph(a.num_nodes, a.compute,
                dict(reversed(list(a.cap.items()))), "c")
    assert c.fingerprint() == a.fingerprint()


def test_fingerprint_sensitive_to_structure():
    base = bidir_ring(6)
    fps = {base.fingerprint()}
    # capacity change
    fps.add(bidir_ring(6, cap=2).fingerprint())
    # node count change
    fps.add(bidir_ring(7).fingerprint())
    # compute/switch partition change (same edges, node 5 demoted to switch)
    fps.add(DiGraph(6, frozenset(range(5)), dict(base.cap)).fingerprint())
    assert len(fps) == 4


def test_compiler_fingerprint_stable():
    assert compiler_fingerprint() == compiler_fingerprint()
    assert len(compiler_fingerprint()) == 16


# ---------------------------------------------------------------------- #
# serialization round-trip (exact Fractions, byte stability)
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("make,p", [
    (fig1a, 8), (lambda: ring(6), 4), (lambda: bidir_ring(5), 4),
    (dragonfly, 4), (lambda: hypercube(3), 4),
])
def test_schedule_roundtrip_exact(make, p):
    sched = compile_allgather(make(), num_chunks=p)
    text = schedule_to_json(sched)
    back = schedule_from_json(text)
    # byte-stable: serialize(deserialize(text)) == text
    assert schedule_to_json(back) == text
    # exact-Fraction fidelity
    assert isinstance(back.opt.inv_x_star, Fraction)
    assert back.opt == sched.opt
    assert back.claimed_runtime == sched.claimed_runtime
    assert back.rounds == sched.rounds
    assert back.path_assignment == sched.path_assignment
    assert back.topo.cap == sched.topo.cap
    assert [(c.root, c.mult, c.verts, c.edges) for c in back.classes] == \
        [(c.root, c.mult, c.verts, c.edges) for c in sched.classes]
    # the deserialized artifact verifies and reproduces its claim exactly
    rep = simulate_allgather(back)
    assert rep.sim_time == back.claimed_runtime


def test_allreduce_roundtrip_exact():
    ar = compile_allreduce(dragonfly(), num_chunks=4)
    text = allreduce_to_json(ar)
    back = allreduce_from_json(text)
    assert allreduce_to_json(back) == text
    rep = simulate_allreduce(back)
    assert rep.sim_time == back.claimed_runtime


def test_reduce_scatter_roundtrip_exact():
    sched = compile_reduce_scatter(fig1a(), num_chunks=4)
    back = schedule_from_json(schedule_to_json(sched))
    rep = simulate_reduce_scatter(back)
    assert rep.sim_time == back.claimed_runtime


@pytest.mark.parametrize("compiler,simulator", [
    (compile_broadcast, simulate_broadcast),
    (compile_reduce, simulate_reduce),
])
def test_rooted_roundtrip_exact(compiler, simulator):
    """Broadcast/reduce artifacts round-trip byte-stably, carry the root,
    and replay to their claimed runtime — including a switched topology."""
    for make, root in ((fig1a, 2), (lambda: bidir_ring(6), 0)):
        sched = compiler(make(), root=root, num_chunks=4)
        text = schedule_to_json(sched)
        back = schedule_from_json(text)
        assert schedule_to_json(back) == text
        assert back.root == root
        assert json.loads(text)["root"] == root
        rep = simulator(back)
        assert rep.sim_time == back.claimed_runtime


# ---------------------------------------------------------------------- #
# on-disk cache: hits skip compilation, keys version the compiler
# ---------------------------------------------------------------------- #

def test_cache_hit_skips_compiler(tmp_path, monkeypatch):
    g = bidir_ring(5)
    ScheduleCache(tmp_path).allgather(g, num_chunks=4)         # miss: compiles

    def boom(*a, **kw):                                        # pragma: no cover
        raise AssertionError("compiler invoked on cache hit")

    monkeypatch.setattr("repro.core.schedule.compile_allgather", boom)
    fresh = ScheduleCache(tmp_path)                            # new process sim
    sched = fresh.allgather(bidir_ring(5, name="renamed"), num_chunks=4)
    assert fresh.stats.hits == 1 and fresh.stats.misses == 0
    assert simulate_allgather(sched).sim_time == sched.claimed_runtime


def test_cache_distinguishes_params(tmp_path):
    c = ScheduleCache(tmp_path)
    c.allgather(ring(4), num_chunks=4)
    c.allgather(ring(4), num_chunks=8)       # different P -> different entry
    c.allgather(ring(5), num_chunks=4)       # different topo
    assert c.stats.misses == 3 and len(c.entries()) == 3
    c.allgather(ring(4), num_chunks=4)
    assert c.stats.hits == 1


def test_cache_compiler_version_invalidates(tmp_path):
    old = ScheduleCache(tmp_path, compiler_fp="deadbeef00000000")
    old.allgather(ring(4), num_chunks=4)
    new = ScheduleCache(tmp_path)            # real fingerprint != deadbeef
    new.allgather(ring(4), num_chunks=4)
    assert new.stats.misses == 1             # stale entry ignored
    assert len(new.entries()) == 2
    assert new.prune_stale() == 1
    assert len(new.entries()) == 1


def test_cache_recovers_from_corrupt_artifact(tmp_path):
    c = ScheduleCache(tmp_path)
    sched = c.allgather(ring(4), num_chunks=4)
    victim = c.path_for(c.key("allgather", ring(4), 4))
    victim.write_text('{"format": "repro.schedule", "vers')   # torn write
    fresh = ScheduleCache(tmp_path)
    with pytest.warns(UserWarning, match="unreadable schedule artifact"):
        again = fresh.allgather(ring(4), num_chunks=4)        # recompiles
    assert fresh.stats.misses == 1 and fresh.stats.puts == 1
    assert again.rounds == sched.rounds


def test_cache_allreduce_and_broadcast(tmp_path):
    c = ScheduleCache(tmp_path)
    ar = c.allreduce(dragonfly(), num_chunks=4)
    bc = c.broadcast(bidir_ring(6), root=2, num_chunks=4)
    c2 = ScheduleCache(tmp_path)
    assert c2.allreduce(dragonfly(), num_chunks=4).claimed_runtime == \
        ar.claimed_runtime
    assert c2.broadcast(bidir_ring(6), root=2, num_chunks=4).rounds == \
        bc.rounds
    # a different broadcast root is a different artifact
    c2.broadcast(bidir_ring(6), root=0, num_chunks=4)
    assert c2.stats.misses == 1


def test_executor_consults_cache(tmp_path, monkeypatch):
    from repro.api import Collectives
    g = ring(4)

    def pair_programs(cache):
        ag, rs = Collectives(cache=cache, num_chunks=4).pair(g)
        from repro.comms import compile_program
        return compile_program(rs), compile_program(ag)

    rs1, ag1 = pair_programs(ScheduleCache(tmp_path))
    monkeypatch.setattr("repro.core.schedule.compile_allgather",
                        lambda *a, **kw: pytest.fail("compiler on hit path"))
    rs2, ag2 = pair_programs(ScheduleCache(tmp_path))

    def sig(prog):
        return [(c.perm, c.width, c.send_slots.tolist(),
                 c.recv_slots.tolist()) for rnd in prog.rounds for c in rnd]

    assert sig(rs1) == sig(rs2) and sig(ag1) == sig(ag2)


# ---------------------------------------------------------------------- #
# golden-schedule regressions
# ---------------------------------------------------------------------- #

GOLDENS = [
    ("fig1a.allgather.p8.json", fig1a,
     lambda g: compile_allgather(g, num_chunks=8), simulate_allgather),
    ("bring8.allgather.p8.json", lambda: bidir_ring(8),
     lambda g: compile_allgather(g, num_chunks=8), simulate_allgather),
    ("two_cluster_3x6.allgather.p8.json",
     lambda: two_cluster_switch(3, 6, 2),
     lambda g: compile_allgather(g, num_chunks=8), simulate_allgather),
    ("fig1a.broadcast.r0.p8.json", fig1a,
     lambda g: compile_broadcast(g, root=0, num_chunks=8),
     simulate_broadcast),
    ("bring8.reduce.r0.p8.json", lambda: bidir_ring(8),
     lambda g: compile_reduce(g, root=0, num_chunks=8), simulate_reduce),
    ("fig1a.alltoall.p1.json", fig1a,
     lambda g: compile_alltoall(g, num_chunks=1), simulate_alltoall),
]


@pytest.mark.parametrize("fname,make,compiler,simulator", GOLDENS)
def test_golden_roundtrip_and_claimed_optimum(fname, make, compiler,
                                              simulator):
    text = (GOLDEN_DIR / fname).read_text()
    sched = schedule_from_json(text)
    # byte-stable round-trip of the checked-in artifact
    assert schedule_to_json(sched) == text
    # the golden schedule still verifies and hits its claimed exact runtime
    rep = simulator(sched)
    assert rep.sim_time == sched.claimed_runtime
    assert sched.topo.fingerprint() == make().fingerprint()


@pytest.mark.parametrize("fname,make,compiler,simulator", GOLDENS)
def test_golden_matches_current_compiler(fname, make, compiler, simulator):
    """Recompiling today must reproduce the checked-in bytes — any compiler
    change that alters emitted schedules has to regenerate the goldens."""
    sched = compiler(make())
    assert schedule_to_json(sched) == (GOLDEN_DIR / fname).read_text()


def test_golden_allreduce_artifact():
    """The nested `repro.allreduce` golden round-trips and both halves
    replay to the combined claim."""
    text = (GOLDEN_DIR / "dragonfly.allreduce.p8.json").read_text()
    ar = allreduce_from_json(text)
    assert allreduce_to_json(ar) == text
    rep = simulate_allreduce(ar)
    assert rep.sim_time == ar.claimed_runtime
    assert allreduce_to_json(compile_allreduce(dragonfly(),
                                               num_chunks=8)) == text


# ---------------------------------------------------------------------- #
# sweep
# ---------------------------------------------------------------------- #

def test_sweep_registry_covers_new_families():
    names = set(sweep_registry())
    for required in ("hypercube3", "bcube2", "meshdgx2x2",
                     "bring8_degraded", "torus3x3_failed"):
        assert required in names
    for name in SMOKE_NAMES:
        assert name in names


def test_sweep_smoke_emits_bench_json(tmp_path):
    out = tmp_path / "BENCH_schedules.json"
    doc = run_sweep(names=SMOKE_NAMES, jobs=1, out_path=str(out),
                    cache_dir=str(tmp_path / "cache"),
                    collectives=("allgather", "broadcast", "reduce",
                                 "allreduce"))
    on_disk = json.loads(out.read_text())
    assert on_disk["format"] == "repro.bench_schedules"
    assert on_disk["num_topologies"] == len(SMOKE_NAMES)
    assert on_disk["num_entries"] == 4 * len(SMOKE_NAMES)
    for e in doc["entries"]:
        assert e["compile_time_s"] >= 0
        assert e["num_chunks"] >= e["depth"]          # P >= depth enforced
        assert Fraction(e["achieved_over_claimed"]) == 1
        assert Fraction(e["achieved_runtime"]) == Fraction(e["claimed_runtime"])
        assert Fraction(e["achieved_over_lb"]) >= 1
        assert e["verified"]
        assert (e["root"] is not None) == (e["kind"] in ("broadcast",
                                                         "reduce"))
    # second sweep over the same cache dir: pure hit path, same results
    doc2 = run_sweep(names=SMOKE_NAMES, jobs=1,
                     cache_dir=str(tmp_path / "cache"),
                     collectives=("allgather", "broadcast", "reduce",
                                  "allreduce"))
    for e1, e2 in zip(doc["entries"], doc2["entries"]):
        assert e1["claimed_runtime"] == e2["claimed_runtime"]
        assert e1["fingerprint"] == e2["fingerprint"]


def test_checked_in_bench_is_current():
    """The committed BENCH_schedules.json was produced by this compiler,
    covers the full collective family on every zoo topology, and every
    entry reproduced its claimed runtime exactly."""
    path = Path(__file__).parent.parent / "BENCH_schedules.json"
    doc = json.loads(path.read_text())
    assert doc["compiler"] == compiler_fingerprint()
    assert doc["num_topologies"] == len(sweep_registry())
    assert list(doc["collectives"]) == list(COLLECTIVES)
    assert doc["num_entries"] == len(sweep_registry()) * len(COLLECTIVES)
    seen = {(e["name"], e["kind"]) for e in doc["entries"]}
    for name in sweep_registry():
        for kind in ("broadcast", "reduce", "allreduce"):
            assert (name, kind) in seen
    # the scaled-up rows are committed (and thus --measured-gateable)
    from repro.cache import LARGE_NAMES
    for name in LARGE_NAMES:
        assert name in sweep_registry()
        assert (name, "allgather") in seen
    for e in doc["entries"]:
        assert Fraction(e["achieved_over_claimed"]) == 1
        if e["kind"] == "alltoall":
            # swept at P = ALLTOALL_CHUNKS: the N-1 destination blocks per
            # tree already fill the pipeline, so P >= depth does not apply
            assert e["num_chunks"] == ALLTOALL_CHUNKS
        else:
            assert e["num_chunks"] >= e["depth"]
        assert e["oracle_probes"] >= 0 and e["oracle_augments"] >= 0


def test_sweep_compile_stats_v6_shape():
    """BENCH v6: ``compile_stats`` is a list of per-stage rows in pipeline
    order, each carrying wall seconds plus the oracle counters, and the
    stage seconds account for (nearly all of) the row's compile time."""
    for kind in ("allgather", "allreduce"):
        e = sweep_one("fig1a", kind=kind, num_chunks=4)
        cs = e["compile_stats"]
        assert isinstance(cs, list)
        stages = [row["stage"] for row in cs]
        assert stages[:3] == ["solve", "split", "pack"]  # pipeline order
        assert len(stages) == len(set(stages))
        for row in cs:
            assert set(row) == {"stage", "seconds", "probes", "augments"}
            assert row["seconds"] >= 0
            assert row["probes"] >= 0 and row["augments"] >= 0
        total = sum(row["seconds"] for row in cs)
        # stage walls are nested inside the compile wall: never (modulo
        # the 1e-6 rounding) larger, and covering almost all of it
        assert total <= e["compile_time_s"] + 1e-3
        assert e["compile_time_s"] - total <= \
            0.25 * e["compile_time_s"] + 0.05
        # the top-level counter sums are the compile_stats column sums
        assert e["oracle_probes"] == sum(r["probes"] for r in cs)
        assert e["oracle_augments"] == sum(r["augments"] for r in cs)


def test_compile_family_parallel_pack_byte_identical():
    """compile_family(jobs=2) runs split+pack in worker processes; the
    emitted artifacts must serialize byte-identically to the sequential
    compile (stats sidecars may differ, schedule bytes may not)."""
    from repro.core.plan import compile_family
    g = fig1a()
    kinds = ("allgather", "reduce_scatter", "allreduce")
    seq = compile_family(g, kinds=kinds, num_chunks=4)
    par = compile_family(g, kinds=kinds, num_chunks=4, jobs=2)
    assert set(seq) == set(par)
    for kind in seq:
        a, b = seq[kind], par[kind]
        if kind == "allreduce":
            assert allreduce_to_json(a) == allreduce_to_json(b)
        else:
            assert schedule_to_json(a) == schedule_to_json(b)


def test_cache_lru_eviction(tmp_path):
    """max_bytes turns on size-capped LRU eviction: recently-used artifacts
    survive, cold ones are deleted, and the just-written artifact is never
    evicted even when it alone exceeds the cap."""
    # measure per-artifact sizes to pick a cap that holds ring4+ring6 but
    # not all three
    sizes = {}
    for n in (4, 5, 6):
        probe = ScheduleCache(tmp_path / f"probe{n}")
        probe.allgather(ring(n), num_chunks=4)
        sizes[n] = probe.size_bytes()
    cap = sizes[4] + sizes[6] + sizes[5] // 2

    c = ScheduleCache(tmp_path / "lru", max_bytes=cap)
    c.allgather(ring(4), num_chunks=4)
    c.allgather(ring(5), num_chunks=4)
    assert c.stats.evictions == 0
    import os
    import time
    # make mtimes strictly ordered, then touch ring(4) via a fresh cache
    for p in sorted((tmp_path / "lru").glob("*.json")):
        os.utime(p, (time.time() - 60, time.time() - 60))
    hot = ScheduleCache(tmp_path / "lru", max_bytes=cap)
    hot.allgather(ring(4), num_chunks=4)           # refreshes recency
    assert hot.stats.hits == 1
    hot.allgather(ring(6), num_chunks=4)           # push over the cap
    assert hot.stats.evictions == 1
    keys = "".join(hot.entries())
    assert hot.key("allgather", ring(4), 4) in keys      # recently used kept
    assert hot.key("allgather", ring(5), 4) not in keys  # LRU victim
    # a fresh cache still replays the survivors
    assert ScheduleCache(tmp_path / "lru").allgather(
        ring(4), num_chunks=4).claimed_runtime is not None


def test_cache_lru_refresh_on_memory_hit(tmp_path):
    """In-memory hits must also refresh the on-disk LRU recency, or a hot
    artifact served from memory becomes the coldest file and gets evicted
    first."""
    import os
    import time
    c = ScheduleCache(tmp_path, max_bytes=1 << 30)
    c.allgather(ring(4), num_chunks=4)
    path = c.path_for(c.key("allgather", ring(4), 4))
    os.utime(path, (time.time() - 3600, time.time() - 3600))
    stale = path.stat().st_mtime
    c.allgather(ring(4), num_chunks=4)               # pure memory hit
    assert c.stats.hits == 1
    assert path.stat().st_mtime > stale


def test_collective_context_broadcast_program(tmp_path):
    """CollectiveContext.broadcast_program: cache-backed, memoized per
    (axis, root), and lowered with the root carried into the program."""
    from repro.comms import CollectiveContext, PermuteProgram
    cache = ScheduleCache(tmp_path)
    ctx = CollectiveContext({"data": 4}, num_chunks=4, schedule_cache=cache)
    prog = ctx.broadcast_program("data", root=1)
    assert isinstance(prog, PermuteProgram)
    assert prog.kind == "broadcast" and prog.root == 1
    assert prog.axis_size == 4
    assert ctx.broadcast_program("data", root=1) is prog   # memoized
    assert ctx.broadcast_program("data", root=0) is not prog
    # a second context replays the cached artifacts instead of compiling
    ctx2 = CollectiveContext({"data": 4}, num_chunks=4,
                             schedule_cache=ScheduleCache(tmp_path))
    prog2 = ctx2.broadcast_program("data", root=1)
    assert ctx2.schedule_cache.stats.hits == 1
    assert [c.perm for rnd in prog2.rounds for c in rnd] == \
        [c.perm for rnd in prog.rounds for c in rnd]


def test_cache_reduce_kind(tmp_path):
    c = ScheduleCache(tmp_path)
    red = c.reduce(fig1a(), root=1, num_chunks=4)
    assert red.kind == "reduce" and red.root == 1
    c2 = ScheduleCache(tmp_path)
    again = c2.reduce(fig1a(), root=1, num_chunks=4)
    assert c2.stats.hits == 1
    assert again.rounds == red.rounds
    assert simulate_reduce(again).sim_time == again.claimed_runtime
