"""§2.3 pack-stage property suite, in isolation from the rest of the
pipeline: every returned `TreeClass` set is a valid packing (spanning,
multiplicities summing to the demand, capacity-respecting — all via
`verify_rooted_packing`), the per-class depth maps are consistent with the
tree edges and with `max_tree_depth`, and pack output is deterministic for
a fixed topology fingerprint (including across oracle substrates).

Random direct-connect inputs come from a seeded Hamiltonian-cycle-sum
generator (Eulerian and strongly connected by construction), pushed
through the §2.1 solve + §2.2 (trivial) split exactly as the compiler
does — the scaled split graph satisfies the Theorem-7 packing condition
by construction, so `pack_arborescences(dstar, k)` must succeed.
"""
import random

import pytest

from repro.core import maxflow as maxflow_mod
from repro.core import plan as plan_mod
from repro.core.arborescence import (max_tree_depth, pack_arborescences,
                                     pack_rooted_trees,
                                     verify_rooted_packing)
from repro.core.graph import DiGraph


def cycle_sum_graph(n: int, r: int, seed: int) -> DiGraph:
    """Sum of r random Hamiltonian cycles on n compute nodes: Eulerian
    (every cycle balances each node) and strongly connected, so the
    compiler's solve/split stages accept it."""
    rng = random.Random(seed)
    cap = {}
    for _ in range(r):
        perm = list(range(n))
        rng.shuffle(perm)
        for i in range(n):
            e = (perm[i], perm[(i + 1) % n])
            cap[e] = cap.get(e, 0) + 1
    return DiGraph(num_nodes=n, compute=frozenset(range(n)), cap=cap,
                   name=f"cyclesum{n}x{r}s{seed}")


def packed_input(n, r, seed):
    """(dstar, k) as the pack stage receives them: the solved, scaled,
    split graph of a random cycle-sum topology."""
    g = cycle_sum_graph(n, r, seed)
    p = plan_mod.plan_for("allgather", g, num_chunks=4, root=None)
    p = plan_mod.split(plan_mod.solve(p))
    return p.split.graph, p.opt.k


CASES = [(4, 1, 0), (5, 2, 1), (6, 2, 2), (6, 3, 3), (8, 2, 4), (8, 4, 5),
         (10, 3, 6), (12, 2, 7)]


def class_signature(classes):
    return [(c.root, c.mult, tuple(c.verts), tuple(c.edges))
            for c in classes]


@pytest.mark.parametrize("n,r,seed", CASES)
def test_pack_is_valid_packing(n, r, seed):
    dstar, k = packed_input(n, r, seed)
    classes = pack_arborescences(dstar, k)
    # pack_arborescences already verifies internally; assert the contract
    # explicitly so this test stands alone
    verify_rooted_packing(dstar, {u: k for u in sorted(dstar.compute)},
                          classes)


@pytest.mark.parametrize("n,r,seed", CASES)
def test_pack_depths_consistent(n, r, seed):
    dstar, k = packed_input(n, r, seed)
    classes = pack_arborescences(dstar, k)
    deepest = 0
    for c in classes:
        parent = c.parent_map()
        for v in c.verts:
            d, node = 0, v
            while node != c.root:
                node = parent[node]
                d += 1
            assert c.depth_of(v) == d
            deepest = max(deepest, d)
    assert max_tree_depth(classes) == deepest


@pytest.mark.parametrize("n,r,seed", CASES[:4])
def test_pack_deterministic_for_fixed_fingerprint(n, r, seed):
    d1, k1 = packed_input(n, r, seed)
    d2, k2 = packed_input(n, r, seed)
    assert (d1.fingerprint(), k1) == (d2.fingerprint(), k2)
    assert (class_signature(pack_arborescences(d1, k1))
            == class_signature(pack_arborescences(d2, k2)))


@pytest.mark.parametrize("n,r,seed", CASES[:4])
def test_pack_deterministic_across_substrates(n, r, seed, monkeypatch):
    """The scipy-CSR and pure-Python maxflow substrates must produce the
    exact same packing — forcing each side via FAST_MIN_ENTRIES."""
    dstar, k = packed_input(n, r, seed)
    monkeypatch.setattr(maxflow_mod, "FAST_MIN_ENTRIES", 0)
    fast = pack_arborescences(dstar, k)
    monkeypatch.setattr(maxflow_mod, "FAST_MIN_ENTRIES", 1 << 30)
    slow = pack_arborescences(dstar, k)
    assert class_signature(fast) == class_signature(slow)


def test_rooted_demands_respected():
    dstar, k = packed_input(6, 3, 9)
    root = min(dstar.compute)
    demands = {root: k}
    classes = pack_rooted_trees(dstar, demands)
    verify_rooted_packing(dstar, demands, classes)
    assert all(c.root == root for c in classes)
    assert sum(c.mult for c in classes) == k


def test_single_node_trivial():
    g = DiGraph(num_nodes=1, compute=frozenset({0}), cap={}, name="one")
    (c,) = pack_rooted_trees(g, {0: 5})
    assert (c.root, c.mult, c.verts, c.edges) == (0, 5, [0], [])


def test_pack_property_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=15, deadline=None)
    @hypothesis.given(n=st.integers(3, 8), r=st.integers(1, 3),
                      seed=st.integers(0, 2**16))
    def run(n, r, seed):
        dstar, k = packed_input(n, r, seed)
        classes = pack_arborescences(dstar, k)
        verify_rooted_packing(dstar, {u: k for u in sorted(dstar.compute)},
                              classes)

    run()
