"""Serving engine end-to-end."""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import build_model
from repro.serve import Request, ServingEngine


@pytest.mark.parametrize("name", ["qwen3-8b", "mamba2-780m"])
def test_engine_batches_and_completes(name):
    cfg = reduced_config(name)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch_size=2, max_len=128)
    for i in range(5):
        eng.submit(Request(uid=i, prompt=np.arange(4 + i, dtype=np.int32) + 1,
                           max_new_tokens=6))
    outs = eng.run()
    assert len(outs) == 5
    for o in outs:
        assert len(o.tokens) == o.prompt_len + 6
        assert (o.tokens[:o.prompt_len] ==
                np.arange(o.prompt_len, dtype=np.int32) + 1).all()


def test_engine_greedy_determinism():
    cfg = reduced_config("qwen3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    outs = []
    for _ in range(2):
        eng = ServingEngine(model, params, batch_size=1, max_len=64)
        eng.submit(Request(uid=0, prompt=np.array([5, 6, 7], np.int32),
                           max_new_tokens=8))
        outs.append(eng.run()[0].tokens)
    np.testing.assert_array_equal(outs[0], outs[1])


def test_launch_serve_survives_injected_link_fault(tmp_path):
    """--inject-fault u-v between boot and parameter distribution: the
    driver hot-swaps the repaired model-axis broadcast program and still
    distributes parameters and serves every request over the degraded
    fabric."""
    import os
    import subprocess
    import sys
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-8b",
         "--reduced", "--host-devices", "4", "--model-parallel", "4",
         "--requests", "2", "--new-tokens", "4",
         "--inject-fault", "0-1"],
        capture_output=True, text=True, timeout=600,
        env=dict(os.environ, PYTHONPATH=src))
    assert out.returncode == 0, f"stderr:\n{out.stderr[-2000:]}"
    assert "[repair] injected link 0-1 failed" in out.stdout
    assert "[repair] axis model broadcast" in out.stdout
    assert "params distributed via tree broadcast" in out.stdout
    assert out.stdout.count("req ") == 2
