"""End-to-end behaviour tests for the paper's system: the full compiler
pipeline (optimality -> edge split -> packing -> schedule -> simulate)
reproduces every quantitative claim in the paper."""
from fractions import Fraction

from repro.core import (compile_allgather, simulate_allgather,
                        solve_optimality, allgather_inv_xstar)
from repro.topo import fig1a, fig1d_ring_unwound, multipod_topology


def test_paper_headline_example():
    """Fig 1a: optimum (M/N)(4/4b); ring unwinding (Fig 1d) is 4x worse;
    the generated pipeline schedule achieves the bound."""
    g = fig1a()
    opt = solve_optimality(g)
    assert opt.inv_x_star == 1          # = 4/4b with b=1, i.e. (M/N)·1
    assert allgather_inv_xstar(fig1d_ring_unwound()) == 4 * opt.inv_x_star

    rep = simulate_allgather(compile_allgather(g, num_chunks=128))
    assert rep.ratio < 1.02             # pipelined -> optimal in the limit


def test_multipod_model_matches_fig1a_structure():
    """Our 2-pod DCN model is the paper's 2-cluster topology: the DCN cut
    dominates and edge splitting preserves its full bandwidth."""
    g = multipod_topology(num_pods=2, nodes_per_pod=4, ici_cap=10,
                          dcn_cap=1)
    opt = solve_optimality(g)
    assert opt.inv_x_star == Fraction(1)
    rep = simulate_allgather(compile_allgather(g, num_chunks=64))
    assert rep.ratio < 1.05
