"""TopologySpec: grammar round-trips, JSON round-trips, registry
equivalence (spec-built graphs are byte-identical to the legacy hand-rolled
builders), canonical transform-derived names, and error behaviour."""
import json
import random

import pytest

from repro.core.graph import DiGraph
from repro.topo import (TopologySpec, TopologySpecError, TransformSpec,
                        bcube, bidir_ring, degrade_link, dgx_box, dragonfly,
                        fail_link, fat_tree, fig1a, hypercube, line,
                        mesh_of_dgx, multipod_topology, resolve_topology,
                        ring, star_switch, circulant, topology_families,
                        torus_2d,
                        two_cluster_switch, zoo_specs)

# ---------------------------------------------------------------------- #
# registry equivalence: every committed zoo entry, spec vs legacy builder
# ---------------------------------------------------------------------- #

# The pre-spec sweep_registry() builders, inlined verbatim: the committed
# ZOO_SPECS table must reproduce every one of these byte-for-byte
# (fingerprints exclude display names, so cache keys cannot move).
LEGACY_REGISTRY = {
    "fig1a": fig1a,
    "fig1a_degraded": lambda: degrade_link(
        two_cluster_switch(4, 10, 2), 0, 8, 1, name="fig1a-deg"),
    "ring8": lambda: ring(8),
    "bring8": lambda: bidir_ring(8),
    "bring8_degraded": lambda: degrade_link(bidir_ring(8, cap=2), 0, 1, 1),
    "line6": lambda: line(6),
    "torus4x4": lambda: torus_2d(4, 4),
    "torus3x3_failed": lambda: fail_link(torus_2d(3, 3), 0, 1),
    "hypercube3": lambda: hypercube(3),
    "hypercube3_failed": lambda: fail_link(hypercube(3), 0, 1),
    "bcube2": lambda: bcube(2),
    "bcube3": lambda: bcube(3),
    "meshdgx2x2": lambda: mesh_of_dgx(2, 2, 2),
    "meshdgx2x2_degraded": lambda: degrade_link(
        mesh_of_dgx(2, 2, 2, nvlink_cap=4, dcn_cap=2), 8, 9, 1),
    "fattree": fat_tree,
    "dragonfly": dragonfly,
    "dgx8": dgx_box,
    "star8": lambda: star_switch(8),
    "circulant8": lambda: circulant(8, 1, 2),
    "circulant16": lambda: circulant(16, 1, 4),
    "two_cluster_3x6": lambda: two_cluster_switch(3, 6, 2),
    "multipod": lambda: multipod_topology(2, 4, 10, 1),
    "torus8x8": lambda: torus_2d(8, 8),
    "torus8x8_failed": lambda: fail_link(torus_2d(8, 8), 0, 1),
    "fattree8p4l2h": lambda: fat_tree(8, 4, 2),
    "fattree8p4l2h_degraded": lambda: degrade_link(
        fat_tree(8, 4, 2, host_cap=2), 0, 64, 1),
    "fattree8p4l4h": lambda: fat_tree(8, 4, 4),
    "dragonfly6x4": lambda: dragonfly(6, 4, 4, 1),
    "dragonfly6x4_degraded": lambda: degrade_link(
        dragonfly(6, 4, 4, 1), 0, 24, 2),
    "torus16x16": lambda: torus_2d(16, 16),
}


def test_zoo_specs_cover_legacy_registry_exactly():
    assert list(zoo_specs()) == list(LEGACY_REGISTRY)


@pytest.mark.parametrize("name", sorted(LEGACY_REGISTRY))
def test_spec_fingerprint_matches_legacy_builder(name):
    spec = zoo_specs()[name]
    built, legacy = spec.build(), LEGACY_REGISTRY[name]()
    assert built.fingerprint() == legacy.fingerprint()
    assert built.canonical_form() == legacy.canonical_form()


def test_sweep_registry_derives_from_zoo_specs():
    from repro.cache import sweep_registry
    reg = sweep_registry()
    assert list(reg) == list(zoo_specs())
    g = reg["torus4x4"]()
    assert g.fingerprint() == torus_2d(4, 4).fingerprint()


# ---------------------------------------------------------------------- #
# grammar: parse / print round-trips
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("text", sorted(
    {str(s) for s in zoo_specs().values()}))
def test_zoo_spec_string_round_trip(text):
    spec = TopologySpec.parse(text)
    assert str(spec) == text
    assert TopologySpec.parse(str(spec)) == spec


def test_compact_and_generic_forms_parse_identically():
    assert TopologySpec.parse("torus2d:8x8") == \
        TopologySpec.parse("torus2d:cols=8,rows=8")
    assert TopologySpec.parse("dragonfly:g6,p4") == \
        TopologySpec.parse("dragonfly:groups=6,per_group=4")
    assert TopologySpec.parse("fattree:8p4l2h") == \
        TopologySpec.parse(
            "fattree:hosts_per_leaf=2,leaf_per_pod=4,pods=8")
    # compact prefix + generic extras
    assert TopologySpec.parse("torus2d:4x4,cap=2") == \
        TopologySpec.parse("torus2d:cap=2,cols=4,rows=4")


def test_bool_params_round_trip():
    spec = TopologySpec.parse("torus2d:3x4,wrap=false")
    assert dict(spec.params)["wrap"] is False
    assert str(spec) == "torus2d:3x4,wrap=false"
    assert spec.build().fingerprint() == \
        torus_2d(3, 4, wrap=False).fingerprint()


def _random_spec(rng: random.Random) -> TopologySpec:
    """A random well-formed spec over a few families (small sizes only so
    the occasional .build() stays cheap)."""
    choices = [
        ("ring", {"n": rng.randint(2, 9), "cap": rng.randint(1, 3)}),
        ("bring", {"n": rng.randint(2, 8)}),
        ("torus2d", {"rows": rng.randint(2, 4), "cols": rng.randint(2, 4),
                     "wrap": rng.random() < 0.5}),
        ("dragonfly", {"groups": rng.randint(2, 4),
                       "per_group": rng.randint(1, 3),
                       "local_cap": rng.randint(1, 5)}),
        ("fattree", {"pods": rng.randint(2, 4),
                     "leaf_per_pod": rng.randint(1, 3),
                     "hosts_per_leaf": rng.randint(1, 3)}),
        ("two_cluster", {"per_cluster": rng.randint(2, 4),
                         "local_cap": rng.randint(2, 10),
                         "global_cap": rng.randint(1, 2)}),
        ("star", {"n": rng.randint(2, 8)}),
    ]
    family, params = rng.choice(choices)
    # randomly drop optional params (required ones must stay)
    fam = topology_families()[family]
    keep = {k: v for k, v in params.items()
            if k in fam.required or rng.random() < 0.7}
    spec = TopologySpec(family=family, params=tuple(keep.items()))
    if rng.random() < 0.4:
        spec = spec.fail(rng.randint(0, 3), rng.randint(4, 7))
    if rng.random() < 0.4:
        spec = spec.degrade(rng.randint(0, 3), rng.randint(4, 7),
                            cap=rng.randint(1, 3))
    return spec


def test_random_specs_round_trip_seeded():
    rng = random.Random(0)
    for _ in range(200):
        spec = _random_spec(rng)
        assert TopologySpec.parse(str(spec)) == spec, str(spec)
        assert TopologySpec.from_json(spec.to_json()) == spec, str(spec)


def test_random_specs_round_trip_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=150, deadline=None)
    @hypothesis.given(st.integers(min_value=0, max_value=2 ** 32 - 1))
    def check(seed):
        spec = _random_spec(random.Random(seed))
        assert TopologySpec.parse(str(spec)) == spec
        assert TopologySpec.from_json(spec.to_json()) == spec

    check()


# ---------------------------------------------------------------------- #
# JSON payloads
# ---------------------------------------------------------------------- #

def test_json_payload_shape_and_stability():
    spec = TopologySpec.parse("meshdgx:2x2x2,dcn_cap=2@degrade(8-9,cap=1)")
    payload = json.loads(spec.to_json())
    assert payload["format"] == "repro.topology_spec"
    assert payload["family"] == "meshdgx"
    assert payload["params"] == {"rows": 2, "cols": 2, "gpus": 2,
                                 "dcn_cap": 2}
    assert payload["transforms"] == [
        {"name": "degrade", "args": [8, 9], "kwargs": {"cap": 1}}]
    # JSON -> spec -> JSON is stable
    again = TopologySpec.from_json(spec.to_json())
    assert again.to_json() == spec.to_json()
    assert again.build().fingerprint() == spec.build().fingerprint()


def test_json_rejects_foreign_payloads():
    with pytest.raises(TopologySpecError):
        TopologySpec.from_dict({"format": "something.else", "family": "ring"})
    with pytest.raises(TopologySpecError):
        TopologySpec.from_json("not json at all")


# ---------------------------------------------------------------------- #
# transforms + canonical names
# ---------------------------------------------------------------------- #

def test_transform_sugar_equals_parsed():
    base = TopologySpec.parse("torus2d:3x3")
    assert base.fail(0, 1) == TopologySpec.parse("torus2d:3x3@fail(0-1)")
    assert base.degrade(0, 1, cap=1) == \
        TopologySpec.parse("torus2d:3x3@degrade(0-1,cap=1)")
    chained = TopologySpec.parse(
        "torus2d:4x4,cap=2@degrade(0-1,cap=1)@fail(1-2)")
    assert chained.transforms == (
        TransformSpec("degrade", (0, 1), (("cap", 1),)),
        TransformSpec("fail", (1, 2)))
    assert chained.build().fingerprint() == fail_link(
        degrade_link(torus_2d(4, 4, cap=2), 0, 1, 1), 1, 2).fingerprint()


def test_degraded_variants_get_canonical_spec_names():
    assert fail_link(torus_2d(3, 3), 0, 1).name == "torus3x3@fail(0-1)"
    assert degrade_link(bidir_ring(8, cap=2), 0, 1, 1).name == \
        "bring8@degrade(0-1,cap=1)"
    # the spec build carries the same canonical name
    assert TopologySpec.parse("torus2d:3x3@fail(0-1)").build().name == \
        "torus3x3@fail(0-1)"
    # explicit name= still overrides (external compatibility)
    assert fail_link(torus_2d(3, 3), 0, 1, name="custom").name == "custom"


# ---------------------------------------------------------------------- #
# resolution + errors
# ---------------------------------------------------------------------- #

def test_resolve_topology_accepts_all_forms():
    g = torus_2d(4, 4)
    assert resolve_topology(g) is g
    assert resolve_topology("torus4x4").fingerprint() == g.fingerprint()
    assert resolve_topology("torus2d:4x4").fingerprint() == g.fingerprint()
    assert resolve_topology(
        TopologySpec.parse("torus2d:4x4")).fingerprint() == g.fingerprint()
    with pytest.raises(TypeError):
        resolve_topology(123)


@pytest.mark.parametrize("bad", [
    "ring",                     # required parameter n missing
    "nosuchfamily:3",
    "ring:8,bogus=1",
    "ring:8@nosuchtransform(0-1)",
    "ring:",
    "ring:n=x",
    "torus2d:8x8,wrap=maybe",
    "ring:8@fail(a-b)",
    "@fail(0-1)",
    "ring:n=1,n=2",
])
def test_malformed_specs_raise(bad):
    with pytest.raises(TopologySpecError):
        TopologySpec.parse(bad)


def test_missing_required_param_raises_at_build():
    with pytest.raises(TopologySpecError):
        TopologySpec(family="ring").build()    # n is required


def test_every_family_registered_with_valid_metadata():
    fams = topology_families()
    # the paper families all registered
    for expected in ("ring", "bring", "line", "full", "torus2d", "torus3d",
                     "hypercube", "star", "two_cluster", "fig1a", "fig1d",
                     "fattree", "dragonfly", "dgx", "bcube", "meshdgx",
                     "multipod", "v5e"):
        assert expected in fams, expected
    for fam in fams.values():
        assert "name" not in fam.param_names
        for f in fam.pattern_fields:
            assert f in fam.param_names
