"""Training substrate: optimizer, microbatching, data determinism,
checkpointing, fault-tolerant supervision, elastic planning."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import build_model
from repro.train import (AdamWConfig, TrainConfig, TrainSupervisor,
                         checkpoint, elastic_plan, init_train_state,
                         make_train_step)
from repro.train.data import DataConfig, host_batch_slice
from repro.train.optimizer import global_norm, lr_schedule


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("qwen3-8b")
    model = build_model(cfg, remat=True)
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    return cfg, model, params, opt, dc


def batch_at(dc, step):
    return {k: jnp.asarray(v) for k, v in
            host_batch_slice(dc, step, 0, dc.global_batch).items()}


def test_loss_decreases(setup):
    cfg, model, params, opt, dc = setup
    step = jax.jit(make_train_step(model, TrainConfig(
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=2))))
    first = last = None
    for i in range(20):
        params, opt, m = step(params, opt, batch_at(dc, 0))  # same batch
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.5, (first, last)


def test_microbatch_equivalence(setup):
    """Grad accumulation must match the single-shot gradient."""
    cfg, model, params, opt, dc = setup
    tc1 = TrainConfig(microbatches=1)
    tc2 = TrainConfig(microbatches=2)
    from repro.train.train_step import loss_and_grad
    batch = batch_at(dc, 3)
    l1, g1, _ = loss_and_grad(model, params, batch, tc1)
    l2, g2, _ = loss_and_grad(model, params, batch, tc2)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-5, rtol=3e-4)


def test_data_determinism_and_slicing():
    dc = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    full = host_batch_slice(dc, 5, 0, 8)["tokens"]
    part = host_batch_slice(dc, 5, 3, 6)["tokens"]
    np.testing.assert_array_equal(full[3:6], part)
    again = host_batch_slice(dc, 5, 0, 8)["tokens"]
    np.testing.assert_array_equal(full, again)
    other = host_batch_slice(dc, 6, 0, 8)["tokens"]
    assert not np.array_equal(full, other)


def test_lr_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(0))) < 0.2
    assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_schedule(cfg, jnp.asarray(99))) == pytest.approx(
        0.1, abs=0.02)


def test_checkpoint_roundtrip_and_gc(setup):
    cfg, model, params, opt, dc = setup
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            checkpoint.save(d, s, (params, opt))
        checkpoint.gc_old(d, keep=2)
        assert checkpoint.all_steps(d) == [3, 4]
        (p2, o2), step = checkpoint.restore(d, (params, opt))
        assert step == 4
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_supervisor_recovers_from_crash(setup):
    cfg, model, params, opt, dc = setup
    step_fn_jit = jax.jit(make_train_step(model, TrainConfig()))
    crashed = {"n": 0}

    def step_fn(step, state):
        if step == 7 and crashed["n"] == 0:
            crashed["n"] += 1
            raise RuntimeError("injected node failure")
        p, o = state
        p, o, m = step_fn_jit(p, o, batch_at(dc, step))
        return (p, o), m

    with tempfile.TemporaryDirectory() as d:
        sup = TrainSupervisor(ckpt_dir=d, ckpt_every=3, max_restarts=2)
        state, final = sup.run(state=(params, opt), num_steps=10,
                               step_fn=step_fn, log=lambda s: None)
        assert final == 10
        assert crashed["n"] == 1


def test_elastic_plan():
    plan = elastic_plan(old_devices=256, new_devices=240, global_batch=256,
                        model_parallel=16)
    assert plan["mesh_shape"] == (15, 16)
    assert plan["microbatch_scale"] >= 1
    with pytest.raises(ValueError):
        elastic_plan(256, 250, 256, 16)   # 250 % 16 != 0


def test_global_norm():
    tree = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert float(global_norm(tree)) == pytest.approx(np.sqrt(3 + 16))


def test_launch_pipeline_collectives(tmp_path):
    """End-to-end launch with --collectives pipeline: gradients cross
    devices through the BucketedAllReduce built from the cached
    `repro.allreduce` artifact (subprocess: forces 4 host devices)."""
    import subprocess
    import sys
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-8b",
         "--reduced", "--steps", "2", "--host-devices", "4",
         "--data-parallel", "4", "--collectives", "pipeline",
         "--schedule-cache", str(tmp_path / "cache"),
         "--ckpt-dir", str(tmp_path / "ckpt"), "--ckpt-every", "100"],
        capture_output=True, text=True, timeout=600,
        env=dict(os.environ, PYTHONPATH=src))
    assert out.returncode == 0, f"stderr:\n{out.stderr[-2000:]}"
    assert "done at step 2" in out.stdout
    # the launch warmed the artifact cache (allreduce + per-axis pair)
    assert any((tmp_path / "cache").glob("allreduce-*.json")), \
        list((tmp_path / "cache").iterdir())
