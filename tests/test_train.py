"""Training substrate: optimizer, microbatching, data determinism,
checkpointing, fault-tolerant supervision, elastic planning."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import build_model
from repro.train import (AdamWConfig, TrainConfig, TrainSupervisor,
                         checkpoint, elastic_plan, init_train_state,
                         make_train_step)
from repro.train.data import DataConfig, host_batch_slice
from repro.train.optimizer import global_norm, lr_schedule


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("qwen3-8b")
    model = build_model(cfg, remat=True)
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    return cfg, model, params, opt, dc


def batch_at(dc, step):
    return {k: jnp.asarray(v) for k, v in
            host_batch_slice(dc, step, 0, dc.global_batch).items()}


def test_loss_decreases(setup):
    cfg, model, params, opt, dc = setup
    step = jax.jit(make_train_step(model, TrainConfig(
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=2))))
    first = last = None
    for i in range(20):
        params, opt, m = step(params, opt, batch_at(dc, 0))  # same batch
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.5, (first, last)


def test_microbatch_equivalence(setup):
    """Grad accumulation must match the single-shot gradient."""
    cfg, model, params, opt, dc = setup
    tc1 = TrainConfig(microbatches=1)
    tc2 = TrainConfig(microbatches=2)
    from repro.train.train_step import loss_and_grad
    batch = batch_at(dc, 3)
    l1, g1, _ = loss_and_grad(model, params, batch, tc1)
    l2, g2, _ = loss_and_grad(model, params, batch, tc2)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-5, rtol=3e-4)


def test_data_determinism_and_slicing():
    dc = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    full = host_batch_slice(dc, 5, 0, 8)["tokens"]
    part = host_batch_slice(dc, 5, 3, 6)["tokens"]
    np.testing.assert_array_equal(full[3:6], part)
    again = host_batch_slice(dc, 5, 0, 8)["tokens"]
    np.testing.assert_array_equal(full, again)
    other = host_batch_slice(dc, 6, 0, 8)["tokens"]
    assert not np.array_equal(full, other)


def test_lr_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(0))) < 0.2
    assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_schedule(cfg, jnp.asarray(99))) == pytest.approx(
        0.1, abs=0.02)


def test_checkpoint_roundtrip_and_gc(setup):
    cfg, model, params, opt, dc = setup
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            checkpoint.save(d, s, (params, opt))
        checkpoint.gc_old(d, keep=2)
        assert checkpoint.all_steps(d) == [3, 4]
        (p2, o2), step = checkpoint.restore(d, (params, opt))
        assert step == 4
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_supervisor_recovers_from_crash(setup):
    cfg, model, params, opt, dc = setup
    step_fn_jit = jax.jit(make_train_step(model, TrainConfig()))
    crashed = {"n": 0}

    def step_fn(step, state):
        if step == 7 and crashed["n"] == 0:
            crashed["n"] += 1
            raise RuntimeError("injected node failure")
        p, o = state
        p, o, m = step_fn_jit(p, o, batch_at(dc, step))
        return (p, o), m

    with tempfile.TemporaryDirectory() as d:
        sup = TrainSupervisor(ckpt_dir=d, ckpt_every=3, max_restarts=2)
        state, final = sup.run(state=(params, opt), num_steps=10,
                               step_fn=step_fn, log=lambda s: None)
        assert final == 10
        assert crashed["n"] == 1


def test_elastic_plan():
    plan = elastic_plan(old_devices=256, new_devices=240, global_batch=256,
                        model_parallel=16)
    assert plan["mesh_shape"] == (15, 16)
    assert plan["microbatch_scale"] >= 1
    with pytest.raises(ValueError):
        elastic_plan(256, 250, 256, 16)   # 250 % 16 != 0


def test_global_norm():
    tree = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert float(global_norm(tree)) == pytest.approx(np.sqrt(3 + 16))


def test_launch_pipeline_collectives(tmp_path):
    """End-to-end launch with --collectives pipeline: gradients cross
    devices through the BucketedAllReduce built from the cached
    `repro.allreduce` artifact (subprocess: forces 4 host devices)."""
    import subprocess
    import sys
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-8b",
         "--reduced", "--steps", "2", "--host-devices", "4",
         "--data-parallel", "4", "--collectives", "pipeline",
         "--schedule-cache", str(tmp_path / "cache"),
         "--ckpt-dir", str(tmp_path / "ckpt"), "--ckpt-every", "100"],
        capture_output=True, text=True, timeout=600,
        env=dict(os.environ, PYTHONPATH=src))
    assert out.returncode == 0, f"stderr:\n{out.stderr[-2000:]}"
    assert "done at step 2" in out.stdout
    # the launch warmed the artifact cache (allreduce + per-axis pair)
    assert any((tmp_path / "cache").glob("allreduce-*.json")), \
        list((tmp_path / "cache").iterdir())


def test_supervisor_restore_resumes_exact_step():
    """Regression for the restore tuple-unpack bug: after a crash the
    supervisor must resume from the checkpoint's (state, step) — replaying
    the exact steps since the last save, not a mangled state tuple."""
    seen = []

    def step_fn(step, state):
        seen.append(step)
        if step == 7 and seen.count(7) == 1:
            raise RuntimeError("injected crash")
        return {"n": state["n"] + 1}, {}

    with tempfile.TemporaryDirectory() as d:
        sup = TrainSupervisor(ckpt_dir=d, ckpt_every=3, max_restarts=1)
        state, final = sup.run(state={"n": jnp.zeros(())}, num_steps=10,
                               step_fn=step_fn, log=lambda s: None)
    assert final == 10
    # crash at 7 restores the step-6 checkpoint and replays 6..9
    assert seen == [0, 1, 2, 3, 4, 5, 6, 7, 6, 7, 8, 9]
    assert int(state["n"]) == 10


@pytest.mark.parametrize("seed", range(25))
def test_elastic_plan_preserves_global_batch(seed):
    """Property: microbatch_scale is the MINIMAL positive integer making
    global_batch * scale divisible by the new data axis, so the summed
    gradient covers exactly the configured global batch."""
    rng = np.random.default_rng(seed)
    mp = int(rng.choice([1, 2, 4, 8, 16]))
    new_data = int(rng.integers(1, 64))
    gb = int(rng.integers(1, 512))
    plan = elastic_plan(old_devices=new_data * mp * 2,
                        new_devices=new_data * mp,
                        global_batch=gb, model_parallel=mp)
    scale = plan["microbatch_scale"]
    assert plan["mesh_shape"] == (new_data, mp)
    assert scale >= 1
    assert (gb * scale) % new_data == 0
    for s in range(1, scale):
        assert (gb * s) % new_data != 0


def test_straggler_monitor_converges_on_persistent_slowdown():
    """A sustained slowdown is flagged at first, then the EWMA walks up to
    the new speed and the flagging stops (the old behaviour dropped
    flagged samples, freezing the mean and flagging every step forever)."""
    from repro.train import StragglerMonitor
    m = StragglerMonitor()
    for i in range(10):
        assert not m.observe(i, 1.0)
    flags = [m.observe(10 + i, 5.0) for i in range(60)]
    assert flags[0]                       # the jump itself is a straggler
    assert not any(flags[-20:])           # ...but the monitor adapts
    assert m.ewma == pytest.approx(5.0, rel=0.05)
    assert len(m.flagged) < 15            # finitely many flags, not 60


def test_fault_injector_parse():
    from repro.train import FaultInjector
    inj = FaultInjector.parse("3:0-12")
    assert (inj.at_step, inj.u, inj.v) == (3, 0, 12)
    for bad in ("", "3", "0-1", "a:0-1", "3:01", "3:a-b"):
        with pytest.raises(ValueError):
            FaultInjector.parse(bad)


def test_supervisor_link_fault_retries_same_step_without_restore():
    from repro.train import FaultInjector, LinkFault
    inj = FaultInjector.parse("4:2-3")
    seen, hooked = [], []

    def step_fn(step, state):
        inj.check(step)
        seen.append(step)
        return {"n": state["n"] + 1}, {}

    with tempfile.TemporaryDirectory() as d:
        sup = TrainSupervisor(ckpt_dir=d, ckpt_every=100,
                              on_link_fault=hooked.append)
        state, final = sup.run(state={"n": jnp.zeros(())}, num_steps=8,
                               step_fn=step_fn, log=lambda s: None)
    assert final == 8
    # the faulted step is retried in place: no step skipped, none replayed
    assert seen == list(range(8))
    assert int(state["n"]) == 8
    assert len(hooked) == 1 and isinstance(hooked[0], LinkFault)
    assert (hooked[0].u, hooked[0].v) == (2, 3)
    assert hooked[0].transform_text == "@fail(2-3)"


def test_supervisor_link_fault_budget_and_no_hook():
    from repro.train import LinkFault

    def always_faulting(step, state):
        raise LinkFault(0, 1)

    with tempfile.TemporaryDirectory() as d:
        sup = TrainSupervisor(ckpt_dir=d, on_link_fault=lambda e: None,
                              max_link_faults=2)
        with pytest.raises(RuntimeError, match="exceeded 2 link faults"):
            sup.run(state={"n": jnp.zeros(())}, num_steps=4,
                    step_fn=always_faulting, log=lambda s: None)
        # without a repair hook a LinkFault is a real crash: it propagates
        # instead of burning the checkpoint-restart budget
        sup2 = TrainSupervisor(ckpt_dir=d)
        with pytest.raises(LinkFault):
            sup2.run(state={"n": jnp.zeros(())}, num_steps=4,
                     step_fn=always_faulting, log=lambda s: None)


def test_launch_train_survives_injected_link_fault(tmp_path):
    """End-to-end ISSUE acceptance: --inject-fault step:u-v on the pipeline
    collectives path.  The LinkFault reaches the supervisor, hot_swap
    repairs the data-axis schedules in place, the step is retried, and the
    run completes every step."""
    import subprocess
    import sys
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-8b",
         "--reduced", "--steps", "3", "--host-devices", "4",
         "--data-parallel", "4", "--collectives", "pipeline",
         "--inject-fault", "1:0-1",
         "--ckpt-dir", str(tmp_path / "ckpt"), "--ckpt-every", "100"],
        capture_output=True, text=True, timeout=600,
        env=dict(os.environ, PYTHONPATH=src))
    assert out.returncode == 0, f"stderr:\n{out.stderr[-2000:]}"
    assert "[ft] link fault at step 1" in out.stdout
    assert "[repair] axis data" in out.stdout
    assert "done at step 3" in out.stdout
    assert "link faults repaired: True" in out.stdout
