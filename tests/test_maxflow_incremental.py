"""Warm-started incremental maxflow engine: flow-preserving capacity
updates equal cold recomputation (exact values, not just verdicts) over
randomized update sequences, adaptive sink ordering never changes oracle
verdicts, the shared §2.2 probers match their one-shot forms, and the
per-stage probe/augment counters ride the compile stats.

(The byte-identity of every golden schedule through the explicit pipeline
stages — the end-to-end guarantee that none of this changed any compiled
artifact — is pinned by tests/test_plan.py.)"""
import random

import pytest

from repro.core.edge_split import (_RootedProber, _TheoremEightProber,
                                   max_discard_capacity, max_split_capacity,
                                   max_split_capacity_rooted,
                                   remove_switches)
from repro.core.graph import DiGraph
from repro.core.maxflow import COUNTERS, FlowNetwork, SourcedNetwork
from repro.core import compile_allgather
from repro.topo import fat_tree, fig1a, two_cluster_switch


def _random_net(rng, n):
    """A FlowNetwork over n+1 nodes (node n = super-source candidate) with
    random edges; returns (net, edge_ids)."""
    net = FlowNetwork(n)
    eids = []
    for _ in range(rng.randint(2 * n, 4 * n)):
        u, v = rng.sample(range(n), 2)
        eids.append(net.add_edge(u, v, rng.randint(0, 9)))
    return net, eids


def _clone_with_caps(net, caps):
    """Fresh zero-flow network with the same edges at capacities `caps`
    (one per forward edge id)."""
    cold = FlowNetwork(net.n)
    for j, c in enumerate(caps):
        cold.add_edge(net.to[2 * j ^ 1], net.to[2 * j], c)
    return cold


# ---------------------------------------------------------------------- #
# FlowNetwork: increase/decrease vs cold recomputation (exact values)
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("seed", range(25))
def test_incremental_cap_updates_match_cold_maxflow(seed):
    """Maintain a maxflow across a random sequence of single-edge capacity
    increases and decreases using only the flow-preserving primitives; the
    maintained value must equal a cold from-scratch maxflow every step."""
    rng = random.Random(seed)
    n = rng.randint(4, 8)
    net, eids = _random_net(rng, n)
    s, t = 0, n - 1
    caps = [net.cap[2 * j] for j in range(len(net.cap) // 2)]
    value = net.maxflow(s, t)
    for _ in range(15):
        j = rng.randrange(len(eids))
        new_cap = rng.randint(0, 9)
        if new_cap >= caps[j]:
            net.increase_edge_cap(2 * j, new_cap)
        else:
            value -= net.decrease_edge_cap(2 * j, new_cap, s, t)
        caps[j] = new_cap
        value += net.maxflow(s, t)      # augment only the delta
        assert value == _clone_with_caps(net, caps).maxflow(s, t)


@pytest.mark.parametrize("seed", range(10))
def test_incremental_updates_respect_limit_probes(seed):
    """Same maintenance loop but with limit-probed (early-exit) flows, the
    shape the §2.2 binary searches use: the maintained value clamped at
    the limit must match the cold limit-probe."""
    rng = random.Random(100 + seed)
    n = rng.randint(4, 7)
    net, eids = _random_net(rng, n)
    s, t = 0, n - 1
    limit = rng.randint(1, 12)
    caps = [net.cap[2 * j] for j in range(len(net.cap) // 2)]
    value = net.maxflow(s, t, limit=limit)
    for _ in range(12):
        j = rng.randrange(len(eids))
        new_cap = rng.randint(0, 9)
        if new_cap >= caps[j]:
            net.increase_edge_cap(2 * j, new_cap)
        else:
            value -= net.decrease_edge_cap(2 * j, new_cap, s, t)
        caps[j] = new_cap
        if value < limit:
            value += net.maxflow(s, t, limit=limit - value)
        assert value == _clone_with_caps(net, caps).maxflow(s, t,
                                                            limit=limit)


# ---------------------------------------------------------------------- #
# SourcedNetwork: warm sweeps == cold sweeps, any adaptive order
# ---------------------------------------------------------------------- #

def _random_sourced(rng, n):
    edges = {}
    for _ in range(rng.randint(n, 3 * n)):
        u, v = rng.sample(range(n), 2)
        edges[(u, v)] = rng.randint(1, 8)
    g = DiGraph(n, frozenset(range(n)), edges, "rand")
    return SourcedNetwork(g, {u: rng.randint(1, 5) for u in range(n)})


@pytest.mark.parametrize("seed", range(20))
def test_warm_sweep_matches_cold_over_random_update_sequences(seed):
    """warm=True sweeps after arbitrary capacity rewrites give exactly the
    cold-network verdict, probe after probe."""
    rng = random.Random(seed)
    n = rng.randint(3, 8)
    warm_net = _random_sourced(rng, n)
    eids = (list(warm_net.eid.values())
            + list(warm_net.src_eid.values()))
    threshold = rng.randint(1, 12)
    sinks = list(range(n - 1))
    for _ in range(12):
        for _ in range(rng.randint(1, 3)):
            warm_net.set_cap_id(rng.choice(eids), rng.randint(0, 10))
        got = warm_net.min_source_flow_at_least(sinks, threshold, warm=True)
        cold = _clone_with_caps(warm_net.net, warm_net._tgt)

        def probe(v):
            cold.reset_flow()
            return cold.maxflow(warm_net.s, v, limit=threshold)

        assert got == all(probe(v) >= threshold for v in sinks)


@pytest.mark.parametrize("seed", range(10))
def test_adaptive_sink_order_never_changes_verdicts(seed):
    """The same capacity state probed through different adaptive-order
    histories (and explicitly shuffled sink arguments) always returns the
    same verdict."""
    rng = random.Random(200 + seed)
    n = rng.randint(3, 7)
    net_a = _random_sourced(rng, n)
    sinks = list(range(n - 1))
    threshold = rng.randint(1, 10)
    # seed net_a's adaptive order with a random probe history
    for _ in range(3):
        net_a.min_source_flow_at_least(
            rng.sample(sinks, len(sinks)), rng.randint(1, 10))
    fresh = SourcedNetwork(net_a.g, {u: 0 for u in range(n)})
    for u, eid in net_a.src_eid.items():
        fresh.set_cap_id(fresh.src_eid[u], net_a._tgt[eid >> 1])
    shuffled = rng.sample(sinks, len(sinks))
    want = fresh.min_source_flow_at_least(sinks, threshold)
    assert net_a.min_source_flow_at_least(sinks, threshold) == want
    assert net_a.min_source_flow_at_least(shuffled, threshold) == want
    assert net_a.min_source_flow_at_least(sinks, threshold,
                                          warm=True) == want


# ---------------------------------------------------------------------- #
# §2.2 probers: shared incremental networks == one-shot oracles
# ---------------------------------------------------------------------- #

def _random_eulerian(seed, n_compute=4, n_switch=2):
    import numpy as np
    rng = np.random.default_rng(seed)
    n = n_compute + n_switch
    edges = {}
    cycles = [list(range(n))]
    for _ in range(int(rng.integers(2, 5))):
        k = int(rng.integers(2, n + 1))
        cycles.append(list(rng.choice(n, size=k, replace=False)))
    for cyc in cycles:
        cap = int(rng.integers(1, 5))
        for i in range(len(cyc)):
            u, v = int(cyc[i]), int(cyc[(i + 1) % len(cyc)])
            if u != v:
                edges[(u, v)] = edges.get((u, v), 0) + cap
    return DiGraph(n, frozenset(range(n_compute)), edges, f"rand{seed}")


@pytest.mark.parametrize("seed", range(8))
def test_shared_prober_matches_one_shot_oracles(seed):
    """A single `_TheoremEightProber` answering many (u, w, t) queries in
    sequence returns exactly what fresh one-shot oracles return — the
    adaptive ordering and in-place gadget toggles leak no state between
    queries."""
    d = _random_eulerian(seed)
    k = 2
    shared = _TheoremEightProber(d, k)
    switches = sorted(d.switches)
    queries = []
    for w in switches:
        ins = sorted(a for (a, b) in d.cap if b == w)
        outs = sorted(b for (a, b) in d.cap if a == w)
        queries += [(u, w, t) for u in ins for t in outs if u != t][:4]
    for (u, w, t) in queries:
        assert shared.split_cap(u, w, t) == max_split_capacity(d, k, u, w, t)
    for w in switches:
        for t in sorted(b for (a, b) in d.cap if a == w)[:2]:
            if d.cap.get((t, w), 0):
                assert shared.discard_cap(t, w) == \
                    max_discard_capacity(d, k, t, w)


@pytest.mark.parametrize("seed", range(8))
def test_shared_rooted_prober_matches_one_shot(seed):
    d = _random_eulerian(seed + 50, n_compute=5, n_switch=1)
    demands = {0: 2, 1: 1}
    shared = _RootedProber(d, demands)
    w = min(d.switches)
    ins = sorted(a for (a, b) in d.cap if b == w)
    outs = sorted(b for (a, b) in d.cap if a == w)
    for u in ins[:3]:
        for t in outs[:3]:
            assert shared.split_cap(u, w, t) == \
                max_split_capacity_rooted(d, demands, u, w, t)


def test_remove_switches_verifies_on_switched_zoo():
    """End-to-end Algorithm 1 with the shared probers keeps the packing
    oracle on real multi-switch fabrics (verify=True re-checks Theorem 5
    on the split result)."""
    for g, k in [(fig1a(), 1), (two_cluster_switch(3, 6, 2), 1),
                 (fat_tree(4, 2, 2), 2)]:
        from repro.core.optimality import solve_optimality
        opt = solve_optimality(g)
        res = remove_switches(g.scaled(opt.U), opt.k, verify=True)
        assert not any(w in e for e in res.graph.cap
                       for w in res.graph.switches)


# ---------------------------------------------------------------------- #
# instrumentation
# ---------------------------------------------------------------------- #

def test_stage_meta_carries_probe_and_augment_counters():
    sched = compile_allgather(fig1a(), num_chunks=8)
    by_stage = {s.stage: s.meta for s in sched.compile_stats.stages}
    for stage in ("solve", "split", "pack"):
        assert by_stage[stage]["probes"] > 0
        assert by_stage[stage]["augments"] > 0
    # the global counters are monotone and cheap to snapshot
    snap = COUNTERS.snapshot()
    compile_allgather(fig1a(), num_chunks=4)
    delta = COUNTERS.delta(snap)
    assert delta["probes"] > 0 and delta["augments"] > 0
