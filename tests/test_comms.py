"""Multi-device execution of the tree-pipeline collectives vs JAX oracles.

Each test spawns a subprocess with XLA_FLAGS=--xla_force_host_platform_
device_count=8 (the main pytest process must keep seeing ONE device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_snippet(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr}\nstdout:\n{out.stdout}"
    return out.stdout


def test_tree_collectives_match_references():
    print(run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        try:
            from jax import shard_map
        except ImportError:  # older jax: experimental namespace
            from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.topo import bidir_ring, fig1a, ring
        from repro.core.schedule import compile_allgather, compile_reduce_scatter
        from repro.comms import compile_program, tree_all_gather, \\
            tree_reduce_scatter, tree_all_reduce

        mesh = Mesh(np.array(jax.devices()), ('x',))
        for topo in (bidir_ring(8), fig1a(), ring(8)):
            ag = compile_program(compile_allgather(topo, num_chunks=4))
            rs = compile_program(compile_reduce_scatter(topo, num_chunks=4))
            x = jax.random.normal(jax.random.PRNGKey(0), (8, 13))
            f = jax.jit(shard_map(lambda v: tree_all_gather(v[0], ag, 'x'),
                                  mesh=mesh, in_specs=P('x'), out_specs=P('x')))
            got = f(x).reshape(8, 8, 13)
            assert np.allclose(got, np.broadcast_to(x[None], (8, 8, 13)),
                               atol=1e-5), topo.name
            h = jax.jit(shard_map(
                lambda v: tree_all_reduce(v[0], rs, ag, 'x')[None],
                mesh=mesh, in_specs=P('x'), out_specs=P('x')))
            got = h(x)
            assert np.allclose(got, np.broadcast_to(x.sum(0), (8, 13)),
                               atol=1e-4), topo.name
            y = jnp.arange(8 * 8 * 4, dtype=jnp.float32).reshape(8, 32)
            g = jax.jit(shard_map(
                lambda v: tree_reduce_scatter(v[0].reshape(8, 4), rs, 'x'),
                mesh=mesh, in_specs=P('x'), out_specs=P('x')))
            assert np.allclose(g(y), y.sum(0).reshape(8, 4)), topo.name
            print('OK', topo.name)
    """))


def test_tree_broadcast_and_reduce_match_references():
    print(run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        try:
            from jax import shard_map
        except ImportError:  # older jax: experimental namespace
            from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.topo import bidir_ring, fig1a
        from repro.core.schedule import compile_broadcast, compile_reduce
        from repro.comms import compile_program, tree_broadcast, tree_reduce

        mesh = Mesh(np.array(jax.devices()), ('x',))
        for topo in (bidir_ring(8), fig1a()):   # incl. a switched topology
            for root in (0, 3):
                bc = compile_program(compile_broadcast(topo, root=root,
                                                       num_chunks=4))
                rd = compile_program(compile_reduce(topo, root=root,
                                                    num_chunks=4))
                assert bc.root == root and rd.root == root
                x = jax.random.normal(jax.random.PRNGKey(root), (8, 13))
                f = jax.jit(shard_map(
                    lambda v: tree_broadcast(v[0], bc, 'x')[None],
                    mesh=mesh, in_specs=P('x'), out_specs=P('x')))
                got = f(x)
                assert np.allclose(got, np.broadcast_to(x[root], (8, 13)),
                                   atol=1e-5), (topo.name, root)
                g = jax.jit(shard_map(
                    lambda v: tree_reduce(v[0], rd, 'x')[None],
                    mesh=mesh, in_specs=P('x'), out_specs=P('x')))
                # MPI_Reduce semantics: the result is defined on the root
                assert np.allclose(g(x)[root], x.sum(0), atol=1e-4), \\
                    (topo.name, root)
                print('OK bc/red', topo.name, 'root', root)
    """))


def test_tree_all_to_all_matches_reference():
    print(run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        try:
            from jax import shard_map
        except ImportError:  # older jax: experimental namespace
            from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.api import Collectives
        from repro.topo import bidir_ring, fig1a
        from repro.comms import tree_all_to_all

        mesh = Mesh(np.array(jax.devices()), ('x',))
        cc = Collectives(num_chunks=1)
        for topo in (bidir_ring(8), fig1a()):
            prog = cc.program(topo, kind='alltoall')
            for shape in ((64, 3, 5), (64, 7)):
                x = jax.random.normal(jax.random.PRNGKey(0), shape)
                f = jax.jit(shard_map(
                    lambda v: tree_all_to_all(v, prog, 'x'),
                    mesh=mesh, in_specs=P('x'), out_specs=P('x')))
                g = jax.jit(shard_map(
                    lambda v: jax.lax.all_to_all(v, 'x', 0, 0),
                    mesh=mesh, in_specs=P('x'), out_specs=P('x')))
                assert np.array_equal(np.asarray(f(x)), np.asarray(g(x))), \\
                    (topo.name, shape)
                print('OK a2a', topo.name, shape)
    """))


def test_moe_forward_alltoall_transport_parity():
    """Expert-parallel MoE under shard_map: the compiled tree_all_to_all
    transport must reproduce the jax.lax.all_to_all transport exactly
    (only the wire schedule differs), and both must match the local
    dense-dispatch moe_forward."""
    print(run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        try:
            from jax import shard_map
        except ImportError:  # older jax: experimental namespace
            from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.api import Collectives
        from repro.topo import bidir_ring
        from repro.comms import tree_all_to_all
        from repro.models.common import ModelConfig
        from repro.models.moe import (init_moe, moe_forward,
                                      moe_forward_alltoall)

        cfg = ModelConfig(name='t', family='moe', num_layers=1, d_model=16,
                          num_heads=2, num_kv_heads=2, d_ff=32,
                          vocab_size=64, num_experts=8,
                          num_experts_per_tok=2, moe_d_ff=24,
                          capacity_factor=2.0)
        p = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8 * 2, 6, 16))
        mesh = Mesh(np.array(jax.devices()), ('x',))
        prog = Collectives(num_chunks=1).program(bidir_ring(8),
                                                 kind='alltoall')

        def run(fwd):
            def body(v):
                y, aux = fwd(v)
                return y, jax.lax.pmean(aux, 'x')
            return jax.jit(shard_map(body, mesh=mesh, in_specs=P('x'),
                                     out_specs=(P('x'), P())))

        y_lax, a_lax = run(
            lambda v: moe_forward_alltoall(p, cfg, v, 'x'))(x)
        y_tree, a_tree = run(
            lambda v: moe_forward_alltoall(
                p, cfg, v, 'x',
                all_to_all=lambda u: tree_all_to_all(u, prog, 'x')))(x)
        assert np.array_equal(np.asarray(y_lax), np.asarray(y_tree))
        assert np.array_equal(np.asarray(a_lax), np.asarray(a_tree))
        # tokens stay data-parallel, experts see every shard: per-shard
        # routing/capacity is identical to a local dense dispatch
        y_loc, _ = run(lambda v: moe_forward(p, cfg, v))(x)
        assert np.allclose(np.asarray(y_lax), np.asarray(y_loc),
                           atol=1e-5)
        print('OK moe alltoall transport parity')
    """))


def test_bucketed_allreduce_from_cached_artifact():
    print(run_snippet("""
        import tempfile
        import jax, jax.numpy as jnp, numpy as np
        try:
            from jax import shard_map
        except ImportError:  # older jax: experimental namespace
            from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.api import Collectives
        from repro.cache import ScheduleCache
        from repro.comms import BucketedAllReduce

        mesh = Mesh(np.array(jax.devices()), ('x',))
        cache_dir = tempfile.mkdtemp()
        ar = Collectives(cache=cache_dir, num_chunks=4).schedule(
            'bring:8', kind='allreduce')
        # replay the single artifact from a fresh cache (no recompilation)
        cache = ScheduleCache(cache_dir)
        ar2 = Collectives(cache=cache, num_chunks=4).schedule(
            'bring:8', kind='allreduce')
        assert cache.stats.hits == 1 and cache.stats.misses == 0
        assert ar2.claimed_runtime == ar.claimed_runtime
        red = BucketedAllReduce.from_schedule(ar2, axis_name='x',
                                              wire_dtype=None)
        x = jax.random.normal(jax.random.PRNGKey(9), (8, 40))
        h = jax.jit(shard_map(lambda v: red({'g': v[0]})['g'][None],
                              mesh=mesh, in_specs=P('x'), out_specs=P('x')))
        assert np.allclose(h(x)[0], x.sum(0), atol=1e-4)
        print('OK bucketed allreduce from one cached artifact')
    """))


def test_multi_axis_hierarchical_allreduce():
    print(run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        try:
            from jax import shard_map
        except ImportError:  # older jax: experimental namespace
            from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.comms.mesh_axes import CollectiveContext
        from repro.comms.collectives import tree_all_reduce_multi

        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ('pod', 'data'))
        ctx = CollectiveContext({'pod': 2, 'data': 4}, num_chunks=4)
        progs = ctx.allreduce_programs(('pod', 'data'))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 11))
        f = jax.jit(shard_map(
            lambda v: tree_all_reduce_multi(v[0], progs)[None],
            mesh=mesh, in_specs=P(('pod', 'data')),
            out_specs=P(('pod', 'data'))))
        got = f(x)
        assert np.allclose(got, np.broadcast_to(x.sum(0), (8, 11)), atol=1e-4)
        print('OK multi-axis', ctx.describe())
    """))


def test_bf16_reduce_scatter_f32_accumulation():
    print(run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        try:
            from jax import shard_map
        except ImportError:  # older jax: experimental namespace
            from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.topo import bidir_ring
        from repro.core.schedule import compile_reduce_scatter
        from repro.comms import compile_program, tree_reduce_scatter

        mesh = Mesh(np.array(jax.devices()), ('x',))
        rs = compile_program(compile_reduce_scatter(bidir_ring(8),
                                                    num_chunks=4))
        y = (jax.random.normal(jax.random.PRNGKey(2), (8, 8, 16)) * 100
             ).astype(jnp.bfloat16)
        g = jax.jit(shard_map(
            lambda v: tree_reduce_scatter(v[0], rs, 'x'),
            mesh=mesh, in_specs=P('x'), out_specs=P('x')))
        got = g(y.reshape(8, -1)).reshape(8, 16)
        ref = y.astype(jnp.float32).sum(0).reshape(8, 16)
        err = np.abs(np.asarray(got, np.float32) - np.asarray(ref)).max()
        rel = err / np.abs(np.asarray(ref)).max()
        assert rel < 2e-2, rel   # f32 accumulation keeps bf16 inputs sane
        print('OK bf16 accum, rel err', rel)
    """))


def test_bucketed_overlap_allreduce():
    print(run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        try:
            from jax import shard_map
        except ImportError:  # older jax: experimental namespace
            from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.topo import bidir_ring
        from repro.core.schedule import compile_allgather, \\
            compile_reduce_scatter
        from repro.comms import compile_program
        from repro.comms.overlap import BucketedAllReduce, partition_buckets

        mesh = Mesh(np.array(jax.devices()), ('x',))
        topo = bidir_ring(8)
        red = BucketedAllReduce(
            rs_prog=compile_program(compile_reduce_scatter(topo, num_chunks=4)),
            ag_prog=compile_program(compile_allgather(topo, num_chunks=4)),
            axis_name='x', bucket_bytes=1 << 10)
        grads = {'a': jax.random.normal(jax.random.PRNGKey(0), (8, 64)),
                 'b': jax.random.normal(jax.random.PRNGKey(1), (128,)),
                 'c': jax.random.normal(jax.random.PRNGKey(2), (4, 4))}
        assert len(partition_buckets(grads, 1 << 10)) >= 2
        def f(g):
            g = jax.tree.map(lambda x: x[0], g)
            return jax.tree.map(lambda x: x[None], red(g))
        per_dev = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (8,) + x.shape), grads)
        got = jax.jit(shard_map(f, mesh=mesh,
                                in_specs=P('x'), out_specs=P('x')))(per_dev)
        for k in grads:
            want = grads[k] * 8
            err = np.abs(np.asarray(got[k][0]) - np.asarray(want)).max()
            assert err < np.abs(np.asarray(want)).max() * 2e-2, (k, err)
        print('OK bucketed overlap allreduce')
    """))
