"""End-to-end tests for the alltoall subsystem: the §2.2/§2.3-shared
compiler (`compile_alltoall`), the certified cut lower bound
(`alltoall_lb`), family amortization byte-identity, cache round-trip and
replay, the typed repair rejection at every entry point, the circulant
zoo family, and the sweep row shape."""
import json
import tempfile
from fractions import Fraction

import pytest

from repro.api import Collectives
from repro.cache import ScheduleCache
from repro.cache.serialize import (ensure_claimed, schedule_from_json,
                                   schedule_to_json)
from repro.core import (alltoall_lb, compile_alltoall, simulate_alltoall,
                        verify_alltoall_delivery)
from repro.core import plan as plan_mod
from repro.core.repair import RepairError, repair_schedule
from repro.topo import bidir_ring, fig1a, hypercube, ring
from repro.topo.spec import TopologySpec
from repro.topo.zoo import ZOO_SPECS, circulant


def zoo_graph(name):
    return TopologySpec.parse(ZOO_SPECS[name]).build()


# ---------------------------------------------------------------------- #
# compiler + simulator
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("topo_fn", [
    lambda: ring(8), lambda: bidir_ring(8), lambda: fig1a(),
    lambda: hypercube(3), lambda: zoo_graph("dgx8"),
    lambda: zoo_graph("circulant8"),
])
def test_compile_verifies_and_beats_no_bound(topo_fn):
    g = topo_fn()
    sched = compile_alltoall(g, num_chunks=1)
    assert sched.kind == "alltoall"
    verify_alltoall_delivery(sched)
    rep = simulate_alltoall(sched)
    assert rep.kind == "alltoall"
    assert rep.sim_time == ensure_claimed(sched), g.name
    assert rep.sim_time >= rep.lb_time, g.name


def test_ring8_achieves_byte_hop_optimum():
    """Unidirectional ring: total byte-hops are M/8 * sum_{i!=j} d(i,j)
    = 28M over 8 unit links, so T >= 7M/2 — and the per-source pruned
    scatter meets it exactly (the cut bound itself is weaker: 2)."""
    rep = simulate_alltoall(compile_alltoall(ring(8), num_chunks=1))
    assert rep.sim_time == Fraction(7, 2)
    assert rep.lb_time == 2


def test_fig1a_achieves_cut_bound_exactly():
    rep = simulate_alltoall(compile_alltoall(fig1a(), num_chunks=1))
    assert rep.sim_time == rep.lb_time == Fraction(1, 2)


def test_multi_chunk_pipelines_verify():
    for p in (2, 4):
        sched = compile_alltoall(bidir_ring(8), num_chunks=p)
        assert sched.num_chunks == p
        verify_alltoall_delivery(sched)


def test_fixed_k_alltoall():
    sched = compile_alltoall(bidir_ring(8), num_chunks=1, fixed_k=1)
    verify_alltoall_delivery(sched)


# ---------------------------------------------------------------------- #
# lower bound: enumerated vs certified-family paths
# ---------------------------------------------------------------------- #

def test_alltoall_lb_exact_small():
    # <= 16 nodes: exhaustive cut enumeration
    assert alltoall_lb(ring(8)) == 2          # contiguous arc, egress 1
    assert alltoall_lb(bidir_ring(8)) == 1    # m(N-m)/(N*2) at m=4
    assert alltoall_lb(hypercube(3)) == Fraction(1, 2)


def test_alltoall_lb_certified_large():
    """20 > _A2A_ENUM_MAX_NODES: the certified family must still find the
    bisection arc (a BFS ball) — m(N-m)/(N*B+) = 10*10/(20*2)."""
    assert alltoall_lb(bidir_ring(20)) == Fraction(5, 2)


# ---------------------------------------------------------------------- #
# family amortization: stages 1-3 are kind-independent
# ---------------------------------------------------------------------- #

def test_family_alltoall_byte_identical_to_cold_compile():
    g = fig1a()
    fam = plan_mod.compile_family(
        g, kinds=("allgather", "reduce_scatter", "alltoall"), num_chunks=4)
    cold = compile_alltoall(g, num_chunks=4)
    assert (schedule_to_json(fam["alltoall"])
            == schedule_to_json(cold))


# ---------------------------------------------------------------------- #
# serialization + cache
# ---------------------------------------------------------------------- #

def test_serialization_round_trip_byte_stable():
    sched = compile_alltoall(bidir_ring(8), num_chunks=2)
    text = schedule_to_json(sched)
    back = schedule_from_json(text)
    assert back.kind == "alltoall"
    assert back.claimed_runtime == sched.claimed_runtime
    assert schedule_to_json(back) == text
    payload = json.loads(text)
    from repro.cache.fingerprint import FORMAT_VERSION
    assert payload["version"] == FORMAT_VERSION


def test_cache_replays_alltoall():
    with tempfile.TemporaryDirectory() as d:
        g = bidir_ring(8)
        first = ScheduleCache(d).alltoall(g, num_chunks=1)
        cache = ScheduleCache(d)
        again = cache.alltoall(g, num_chunks=1)
        assert cache.stats.hits == 1 and cache.stats.misses == 0
        assert schedule_to_json(again) == schedule_to_json(first)


def test_facade_schedule_and_program():
    cc = Collectives(num_chunks=1)
    sched = cc.schedule("bring:8", kind="alltoall")
    assert sched.kind == "alltoall"
    prog = cc.lower(sched)
    assert prog.kind == "alltoall"
    assert prog.axis_size == 8
    assert prog.slots_per_shard == 8 * sched.opt.k * sched.num_chunks


# ---------------------------------------------------------------------- #
# repair: rejected with a typed error at every entry point
# ---------------------------------------------------------------------- #

def test_repair_schedule_rejects_alltoall():
    sched = compile_alltoall(bidir_ring(8), num_chunks=1)
    with pytest.raises(RepairError, match="alltoall"):
        repair_schedule(sched, "@degrade(0-1,cap=1)")


def test_facade_repair_rejects_alltoall_artifact_and_spec():
    cc = Collectives(num_chunks=1)
    sched = cc.schedule("bring:8", kind="alltoall")
    with pytest.raises(RepairError, match="alltoall"):
        cc.repair(sched, "@degrade(0-1,cap=1)")
    with pytest.raises(RepairError, match="alltoall"):
        cc.repair("bring:8", "@degrade(0-1,cap=1)", kind="alltoall")


def test_hot_swap_rejects_axis_with_alltoall_program():
    from repro.comms.mesh_axes import CollectiveContext
    ctx = CollectiveContext({"x": 8}, num_chunks=2)
    ctx.alltoall_program("x")
    with pytest.raises(RepairError, match="alltoall"):
        ctx.hot_swap("@degrade(0-1,cap=1)")


# ---------------------------------------------------------------------- #
# circulant zoo family
# ---------------------------------------------------------------------- #

def test_circulant_registered_and_wellformed():
    for name in ("circulant8", "circulant16"):
        g = zoo_graph(name)
        assert g.num_compute == int(name[len("circulant"):])
        # vertex-transitive direct-connect fabric: Eulerian by symmetry
        for v in g.compute:
            assert (sum(c for (a, b), c in g.cap.items() if a == v)
                    == sum(c for (a, b), c in g.cap.items() if b == v))
    g = circulant(8, 1, 2)
    assert g.name == "circulant8s1-2"
    assert len(g.cap) == 8 * 4          # strides 1,2 in both directions
    with pytest.raises(ValueError):
        circulant(8, 0, 2)
    with pytest.raises(ValueError):
        circulant(8, 3, 2)


def test_circulant_stride_wraparound_accumulates_capacity():
    # on n=4, stride 2 meets itself (2s = n): both directions pile onto
    # the same physical link, so capacity doubles instead of duplicating
    g = circulant(4, 2, 2)
    assert g.cap[(0, 2)] == 2 and g.cap[(2, 0)] == 2


# ---------------------------------------------------------------------- #
# sweep row
# ---------------------------------------------------------------------- #

def test_sweep_emits_alltoall_row():
    from repro.cache.sweep import ALLTOALL_CHUNKS, run_sweep
    doc = run_sweep(names=["bring8"], num_chunks=4, jobs=1,
                    collectives=["allgather", "alltoall"])
    rows = [r for r in doc["entries"] if r["kind"] == "alltoall"]
    assert len(rows) == 1
    row = rows[0]
    assert row["topology"] == "bring8"
    assert row["num_chunks"] == ALLTOALL_CHUNKS
    assert row["achieved_runtime"] == row["claimed_runtime"]
    assert row["verified"] is True
