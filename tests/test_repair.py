"""Online schedule repair (`repro.core.repair` + `Collectives.repair`):
byte-equality of repaired artifacts against cold compiles across the
whole zoo, warm-path engagement, the v5 `.repair` cache sidecars, and
`CollectiveContext.hot_swap`."""
import json

import pytest

from repro.api import Collectives
from repro.cache.serialize import allreduce_to_json, schedule_to_json
from repro.cache.sweep import LARGE_NAMES
from repro.core import plan as plan_mod
from repro.core.repair import (WARM, RepairError, RepairReport,
                               repair_artifact, repair_schedule)
from repro.topo.spec import TopologySpec, TransformSpec, zoo_specs
from repro.topo.zoo import fail_link

SMALL_ZOO = sorted(n for n in zoo_specs() if n not in LARGE_NAMES)


def compile_cold(kind, g, num_chunks=4, root=None):
    p = plan_mod.plan_for(kind, g, num_chunks=num_chunks, root=root)
    return plan_mod.emit(plan_mod.rounds(plan_mod.pack(
        plan_mod.split(plan_mod.solve(p)))))


# ---------------------------------------------------------------------- #
# choosing a valid fault per topology (each zoo graph has different link
# capacities, and failing a cut edge would disconnect the fabric)
# ---------------------------------------------------------------------- #

def _symmetric_links(g):
    return sorted((u, v) for (u, v), c in g.cap.items()
                  if u < v and g.cap.get((v, u)) == c)


def _connected(g):
    """Every node touched by capacity (plus every compute node) mutually
    reachable — `is_eulerian` only checks degree balance, not cuts."""
    nodes = {u for e in g.cap for u in e} | set(g.compute)
    if not nodes:
        return False
    fwd, rev = {}, {}
    for (u, v) in g.cap:
        fwd.setdefault(u, []).append(v)
        rev.setdefault(v, []).append(u)

    def reach(adj):
        start = min(nodes)
        seen, stack = {start}, [start]
        while stack:
            for y in adj.get(stack.pop(), ()):
                if y not in seen:
                    seen.add(y)
                    stack.append(y)
        return seen

    return nodes <= reach(fwd) and nodes <= reach(rev)


def pick_fail(g):
    """First symmetric link whose removal keeps the graph Eulerian AND
    connected, as ``@fail(u-v)`` text; None when no link survives."""
    for u, v in _symmetric_links(g):
        try:
            if _connected(fail_link(g, u, v)):
                return f"@fail({u}-{v})"
        except ValueError:
            continue
    return None


def pick_degrade(g):
    """First symmetric link with capacity headroom, degraded by one unit;
    None on unit-capacity fabrics (degrade_link requires 0 < cap < cur)."""
    for u, v in _symmetric_links(g):
        if g.cap[(u, v)] >= 2:
            return f"@degrade({u}-{v},cap={g.cap[(u, v)] - 1})"
    return None


# ---------------------------------------------------------------------- #
# zoo-wide byte equality: repaired == cold compile of the degraded spec
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("picker", [pick_fail, pick_degrade],
                         ids=["fail", "degrade"])
@pytest.mark.parametrize("name", SMALL_ZOO)
def test_zoo_repair_bytes_equal_cold(name, picker):
    base = zoo_specs()[name].build()
    tr = picker(base)
    if tr is None:
        pytest.skip(f"{name}: no applicable link for {picker.__name__}")
    WARM.clear()
    art = compile_cold("allgather", base)
    cold = compile_cold("allgather",
                        TransformSpec.parse_text(tr).apply(base))
    rep, report = repair_schedule(art, tr)
    assert schedule_to_json(rep) == schedule_to_json(cold)
    assert report.verified
    assert report.transform == str(TransformSpec.parse_text(tr))
    assert report.base_topology == base.name


@pytest.mark.parametrize("kind,root", [("reduce_scatter", None),
                                       ("broadcast", 0), ("reduce", 2)])
def test_repair_other_kinds_bytes_equal(kind, root):
    base = TopologySpec.parse("multipod:2x4").build()
    tr = "@degrade(0-9,cap=5)"
    WARM.clear()
    art = compile_cold(kind, base, root=root)
    cold = compile_cold(kind, TransformSpec.parse_text(tr).apply(base),
                        root=root)
    rep, report = repair_schedule(art, tr)
    assert schedule_to_json(rep) == schedule_to_json(cold)
    if root is not None:
        assert rep.root == root
        assert report.solve_rounds == 0     # Appendix-A rooted path


def test_repair_allreduce_composes_both_halves():
    base = TopologySpec.parse("fig1a").build()
    tr = "@fail(0-9)"
    coll = Collectives(num_chunks=4)
    ar = coll.schedule(base, kind="allreduce")
    rep, report = repair_artifact(ar, tr)
    cold = coll.schedule(TransformSpec.parse_text(tr).apply(base),
                         kind="allreduce")
    assert allreduce_to_json(rep) == allreduce_to_json(cold)
    assert report.kind == "allreduce"
    assert report.verified


# ---------------------------------------------------------------------- #
# warm paths: the whole point of repair vs recompiling
# ---------------------------------------------------------------------- #

def test_repair_engages_warm_solve_and_split():
    """On a switched fabric under an optimum-preserving degrade, both the
    solve-network transplant and the split trace replay must engage (the
    perf gate in tools/perf_smoke.py times exactly this configuration)."""
    base = TopologySpec.parse("fig1a").build()
    WARM.clear()
    art = compile_cold("allgather", base)
    _, report = repair_schedule(art, "@degrade(0-9,cap=9)")
    assert report.warm_solve
    assert report.warm_split
    assert not report.cached


def test_repair_cold_fallback_still_exact():
    """With the warm store emptied (base compiled in another process, or
    evicted), repair falls back to cold oracle state but stays exact."""
    base = TopologySpec.parse("fig1a").build()
    WARM.clear()
    art = compile_cold("allgather", base)
    WARM.clear()                          # simulate eviction
    cold = compile_cold(
        "allgather",
        TransformSpec.parse_text("@degrade(0-9,cap=9)").apply(base))
    rep, report = repair_schedule(art, "@degrade(0-9,cap=9)")
    assert not report.warm_solve and not report.warm_split
    assert schedule_to_json(rep) == schedule_to_json(cold)


# ---------------------------------------------------------------------- #
# error surface
# ---------------------------------------------------------------------- #

def test_repair_rejects_inapplicable_transform():
    art = compile_cold("allgather", TopologySpec.parse("fig1a").build())
    with pytest.raises(RepairError, match="does not apply"):
        repair_schedule(art, "@fail(90-91)")
    with pytest.raises(RepairError, match="does not apply"):
        # 0-8 is a unit-capacity compute->switch link: nothing to degrade
        repair_schedule(art, "@degrade(0-8,cap=1)")


def test_repair_rejects_fixed_k_compiles():
    coll = Collectives(num_chunks=4, fixed_k=2)
    art = coll.schedule("bring:8,cap=2")
    with pytest.raises(RepairError):
        coll.repair(art, "@degrade(0-1,cap=1)")


def test_report_roundtrips_and_ignores_future_fields():
    _, report = repair_artifact(
        compile_cold("allgather", TopologySpec.parse("fig1a").build()),
        "@fail(0-9)")
    d = report.to_dict()
    d["some_v6_field"] = 1                # forward compat: extra keys drop
    back = RepairReport.from_dict(d)
    assert back == RepairReport.from_dict(report.to_dict())
    assert back.transform == "@fail(0-9)"


# ---------------------------------------------------------------------- #
# v5 cache: transform-keyed .repair sidecars + natural-key artifacts
# ---------------------------------------------------------------------- #

def test_repair_cache_sidecar_replay(tmp_path):
    coll = Collectives(cache=tmp_path, num_chunks=4)
    tr = "@degrade(0-9,cap=5)"
    art = coll.schedule("fig1a")
    rep1, r1 = coll.repair(art, tr)
    assert not r1.cached
    sidecars = list(tmp_path.glob("*.repair"))
    assert len(sidecars) == 1
    doc = json.loads(sidecars[0].read_text())
    assert doc["format"] == "repro.repair"
    assert doc["transform"] == tr
    assert doc["base_fingerprint"] == art.topo.fingerprint()

    # replay: same (base, transform) never recompiles; the report keeps
    # the ORIGINAL wall time and flags cached=True
    rep2, r2 = coll.repair(art, tr)
    assert r2.cached
    assert r2.repair_time_s == r1.repair_time_s
    assert schedule_to_json(rep2) == schedule_to_json(rep1)

    # the artifact sits under its natural degraded-topology key: a plain
    # schedule() of the degraded spec (fresh facade, same cache dir) hits
    # it instead of compiling
    coll2 = Collectives(cache=tmp_path, num_chunks=4)
    direct = coll2.schedule(f"fig1a{tr}")
    assert schedule_to_json(direct) == schedule_to_json(rep1)


def test_repair_cache_dangling_sidecar_is_miss(tmp_path):
    coll = Collectives(cache=tmp_path, num_chunks=4)
    art = coll.schedule("fig1a")
    rep1, _ = coll.repair(art, "@fail(0-9)")
    doc = json.loads(next(tmp_path.glob("*.repair")).read_text())
    (tmp_path / f"{doc['artifact_key']}.json").unlink()
    # fresh facade (no in-memory memo of the evicted artifact): the
    # dangling sidecar degrades to a clean miss and the repair re-runs
    coll2 = Collectives(cache=tmp_path, num_chunks=4)
    rep2, r2 = coll2.repair(art, "@fail(0-9)")
    assert not r2.cached
    assert schedule_to_json(rep2) == schedule_to_json(rep1)


def test_repair_cache_clear_removes_sidecars(tmp_path):
    coll = Collectives(cache=tmp_path, num_chunks=4)
    coll.repair(coll.schedule("fig1a"), "@fail(0-9)")
    assert list(tmp_path.glob("*.repair"))
    coll.cache.clear()
    assert not list(tmp_path.glob("*.repair"))
    assert not list(tmp_path.glob("*.json"))


def test_repair_accepts_spec_instead_of_artifact(tmp_path):
    coll = Collectives(cache=tmp_path, num_chunks=4)
    rep, report = coll.repair("fig1a", "@degrade(0-9,cap=5)")
    assert report.base_topology == coll.topology("fig1a").name
    assert schedule_to_json(rep) == schedule_to_json(
        coll.schedule("fig1a@degrade(0-9,cap=5)"))


# ---------------------------------------------------------------------- #
# hot swap: the online path the fault-tolerance loop drives
# ---------------------------------------------------------------------- #

def test_hot_swap_repairs_every_compiled_program():
    from repro.comms import CollectiveContext
    coll = Collectives(num_chunks=4)
    ctx = CollectiveContext({"data": 8, "model": 1},
                            topologies={"data": "bring:8,cap=2"},
                            collectives=coll)
    ctx.axis("data")
    ctx.allreduce_schedule("data")
    ctx.broadcast_program("data", root=0)

    reports = ctx.hot_swap("@degrade(0-1,cap=1)")
    assert set(reports) == {"data"}
    kinds = sorted(r.kind for r in reports["data"])
    assert kinds == ["allgather", "allreduce", "broadcast", "reduce_scatter"]

    # the swapped-in programs are exactly a cold compile of the degraded
    # fabric, and later compiles see the degraded topology
    deg = TransformSpec.parse_text("@degrade(0-1,cap=1)").apply(
        TopologySpec.parse("bring:8,cap=2").build())
    assert ctx.topology("data").cap[(0, 1)] == 1
    assert schedule_to_json(ctx.axis("data").ag_sched) == \
        schedule_to_json(coll.schedule(deg, kind="allgather"))
    assert allreduce_to_json(ctx.allreduce_schedule("data")) == \
        allreduce_to_json(coll.schedule(deg, kind="allreduce"))


def test_hot_swap_untouched_axes_and_atomicity():
    from repro.comms import CollectiveContext
    ctx = CollectiveContext({"data": 8, "model": 1},
                            topologies={"data": "bring:8,cap=2"},
                            collectives=Collectives(num_chunks=4))
    before = schedule_to_json(ctx.axis("data").ag_sched)
    # no axis carries link 90-91: must raise and leave programs untouched
    with pytest.raises(ValueError, match="applies to no axis"):
        ctx.hot_swap("@fail(90-91)")
    # a fault that disconnects the ring raises mid-repair; the staged
    # commit means the context still serves the intact programs
    with pytest.raises((ValueError, RepairError)):
        ctx.hot_swap("@degrade(0-1,cap=0)")
    assert schedule_to_json(ctx.axis("data").ag_sched) == before
    assert ctx.topology("data").cap[(0, 1)] == 2
