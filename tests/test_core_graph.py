"""Unit + property tests for the paper's core: maxflow, optimality search,
edge splitting, arborescence packing.

Property tests run twice: a deterministic seeded-``random.Random`` pass that
always runs, and a wider ``hypothesis`` pass that is skipped when the
dependency is not installed (``pytest.importorskip``)."""
import math
import random
from fractions import Fraction

import networkx as nx
import numpy as np
import pytest

from repro.core import (DiGraph, FlowNetwork, allgather_inv_xstar,
                        brute_force_inv_xstar, choose_U_k, max_tree_depth,
                        oracle_feasible, pack_arborescences, pack_rooted_trees,
                        remove_switches, simplest_between, solve_fixed_k,
                        solve_optimality, trivial_split, verify_packing,
                        expand_paths)
from repro.core.edge_split import _oracle_holds
from repro.topo import (bidir_ring, dgx_box, dragonfly, fat_tree, fig1a,
                        fig1d_ring_unwound, fully_connected, ring, star_switch,
                        torus_2d, two_cluster_switch)


# ---------------------------------------------------------------------- #
# maxflow
# ---------------------------------------------------------------------- #

def _random_digraph(rng, n, p, max_cap=9):
    edges = {}
    for u in range(n):
        for v in range(n):
            if u != v and rng.random() < p:
                edges[(u, v)] = int(rng.integers(1, max_cap + 1))
    return edges


@pytest.mark.parametrize("seed", range(5))
def test_dinic_matches_networkx(seed):
    rng = np.random.default_rng(seed)
    n = 8
    edges = _random_digraph(rng, n, 0.4)
    if not edges:
        pytest.skip("empty graph")
    net = FlowNetwork(n)
    g = nx.DiGraph()
    for (u, v), c in edges.items():
        net.add_edge(u, v, c)
        g.add_edge(u, v, capacity=c)
    for (s, t) in [(0, n - 1), (1, 2), (3, 0)]:
        want = nx.maximum_flow_value(g, s, t) if g.has_node(s) and \
            g.has_node(t) and s in g and t in g else 0
        try:
            want = nx.maximum_flow_value(g, s, t)
        except nx.NetworkXError:
            want = 0
        assert net_copy(edges, n).maxflow(s, t) == want


def net_copy(edges, n):
    net = FlowNetwork(n)
    for (u, v), c in edges.items():
        net.add_edge(u, v, c)
    return net


def test_maxflow_limit_early_exit():
    net = FlowNetwork(2)
    net.add_edge(0, 1, 1000)
    assert net.maxflow(0, 1, limit=7) == 7


# ---------------------------------------------------------------------- #
# simplest_between (Prop 2 recovery)
# ---------------------------------------------------------------------- #

def _check_simplest_between(a: Fraction, b: Fraction) -> None:
    lo, hi = min(a, b), max(a, b)
    r = simplest_between(lo, hi)
    assert lo <= r <= hi
    # minimality of denominator (r.denominator <= 200 by construction:
    # endpoints have denominator <= 200 and r is the simplest in between)
    for den in range(1, r.denominator):
        lo_num = math.ceil(lo * den)
        assert lo_num > hi * den, \
            f"{lo_num}/{den} in [{lo},{hi}] beats {r}"


def _random_bounded_fraction(rng: random.Random, max_value: int = 50,
                             max_denominator: int = 200) -> Fraction:
    den = rng.randint(1, max_denominator)
    return Fraction(rng.randint(0, max_value * den), den)


@pytest.mark.parametrize("seed", range(8))
def test_simplest_between_in_interval_seeded(seed):
    rng = random.Random(seed)
    for _ in range(10):
        _check_simplest_between(_random_bounded_fraction(rng),
                                _random_bounded_fraction(rng))


def test_simplest_between_in_interval_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=80, deadline=None)
    @hypothesis.given(
        st.fractions(min_value=0, max_value=50, max_denominator=200),
        st.fractions(min_value=0, max_value=50, max_denominator=200))
    def check(a, b):
        _check_simplest_between(a, b)

    check()


# ---------------------------------------------------------------------- #
# optimality binary search == brute force (property, random Eulerian)
# ---------------------------------------------------------------------- #

def _random_eulerian(seed, n_compute=4, n_switch=1, max_cap=4):
    """Random Eulerian digraph built from random directed cycles (cycle
    sums are always Eulerian), guaranteeing compute-node reachability."""
    rng = np.random.default_rng(seed)
    n = n_compute + n_switch
    edges = {}
    nodes = list(range(n))
    # a base cycle through everything keeps it connected
    cycles = [nodes[:]]
    for _ in range(int(rng.integers(1, 5))):
        k = int(rng.integers(2, n + 1))
        cyc = list(rng.choice(n, size=k, replace=False))
        cycles.append(cyc)
    for cyc in cycles:
        cap = int(rng.integers(1, max_cap + 1))
        for i in range(len(cyc)):
            u, v = int(cyc[i]), int(cyc[(i + 1) % len(cyc)])
            if u != v:
                edges[(u, v)] = edges.get((u, v), 0) + cap
    return DiGraph(n, frozenset(range(n_compute)), edges, f"rand{seed}")


@pytest.mark.parametrize("seed", range(12))
def test_optimality_matches_brute_force(seed):
    g = _random_eulerian(seed)
    got = allgather_inv_xstar(g)
    want = brute_force_inv_xstar(g)
    assert got == want, f"{g.name}: search {got} != brute {want}"


def test_fig1a_matches_paper():
    g = fig1a()
    opt = solve_optimality(g)
    # paper §2.1: 1/x* = 4/4b = 1 (b=1), U = 1, k = 1
    assert opt.inv_x_star == 1
    assert opt.U == 1
    assert opt.k == 1


def test_fig1d_ring_unwinding_is_4x_worse():
    assert allgather_inv_xstar(fig1d_ring_unwound()) == 4
    assert allgather_inv_xstar(fig1a()) == 1


@pytest.mark.parametrize("make,expect", [
    (lambda: ring(4), Fraction(3)),
    (lambda: ring(8), Fraction(7)),
    (lambda: fully_connected(4), Fraction(1)),
    (lambda: star_switch(4), Fraction(3)),
    (lambda: torus_2d(2, 2), Fraction(3, 4)),
])
def test_known_optima(make, expect):
    assert allgather_inv_xstar(make()) == expect


# ---------------------------------------------------------------------- #
# edge splitting invariants (Theorem 7/8)
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("seed", range(10))
def test_edge_split_preserves_invariants(seed):
    g = _random_eulerian(seed, n_compute=4, n_switch=2)
    if not any(w in e for e in g.cap for w in g.switches):
        pytest.skip("no switch edges")
    opt = solve_optimality(g)
    scaled = g.scaled(opt.U)
    res = remove_switches(scaled, opt.k, verify=True)
    star = res.graph
    assert star.is_eulerian()
    assert not any(w in e for e in star.cap for w in star.switches)
    assert _oracle_holds(star, opt.k)
    # path expansion is an exact flow decomposition
    paths = expand_paths(res)
    for (u, t), plist in paths.items():
        assert sum(c for _, c in plist) == star.cap[(u, t)]
        for path, _ in plist:
            assert path[0] == u and path[-1] == t
            assert all(w in res.original.switches for w in path[1:-1])


@pytest.mark.parametrize("make", [fig1a, fat_tree, dragonfly, dgx_box,
                                  lambda: two_cluster_switch(3, 5, 1)])
def test_edge_split_zoo(make):
    g = make()
    opt = solve_optimality(g)
    res = remove_switches(g.scaled(opt.U), opt.k, verify=True)
    # optimal runtime unchanged on the logical graph (scaled by U)
    star_inv = allgather_inv_xstar(res.graph)
    assert star_inv * opt.U == opt.inv_x_star * 1, \
        f"{g.name}: D* optimum {star_inv} vs {opt.inv_x_star}/U"


# ---------------------------------------------------------------------- #
# arborescence packing (Theorem 9-12)
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("seed", range(10))
def test_packing_random_direct_graphs(seed):
    g = _random_eulerian(seed, n_compute=5, n_switch=0)
    opt = solve_optimality(g)
    classes = pack_arborescences(g.scaled(opt.U), opt.k)
    verify_packing(g.scaled(opt.U), opt.k, classes)


def test_broadcast_packing():
    g = bidir_ring(6)
    classes = pack_rooted_trees(g, {0: 2})   # λ(0) = 2 on a bidir ring
    assert sum(c.mult for c in classes) == 2
    for c in classes:
        assert set(c.verts) == set(range(6))


# ---------------------------------------------------------------------- #
# randomized end-to-end properties (seeded random.Random — no hypothesis)
# ---------------------------------------------------------------------- #

def _random_eulerian_py(rng: random.Random, n_compute: int, n_switch: int,
                        max_cap: int = 3) -> DiGraph:
    """Pure-stdlib analogue of `_random_eulerian`: sum of random directed
    cycles (always Eulerian), with one base cycle through every node so all
    compute nodes are mutually reachable."""
    n = n_compute + n_switch
    base = list(range(n))
    rng.shuffle(base)
    cycles = [base]
    for _ in range(rng.randint(1, 4)):
        cycles.append(rng.sample(range(n), rng.randint(2, n)))
    edges = {}
    for cyc in cycles:
        cap = rng.randint(1, max_cap)
        for i in range(len(cyc)):
            u, v = cyc[i], cyc[(i + 1) % len(cyc)]
            if u != v:
                edges[(u, v)] = edges.get((u, v), 0) + cap
    return DiGraph(n, frozenset(range(n_compute)), edges, "pyrand")


@pytest.mark.parametrize("seed", range(50))
def test_random_topology_search_and_packing(seed):
    """~50 random connected digraphs: the binary search matches the
    exponential brute force, and the packing invariants hold after edge
    splitting — the paper's §2 pipeline end to end."""
    rng = random.Random(seed)
    g = _random_eulerian_py(rng, n_compute=rng.randint(3, 5),
                            n_switch=rng.randint(0, 2))
    got = allgather_inv_xstar(g)
    want = brute_force_inv_xstar(g)
    assert got == want, f"seed {seed}: search {got} != brute {want}"
    opt = solve_optimality(g)
    scaled = g.scaled(opt.U)
    if any(w in e for e in scaled.cap for w in scaled.switches):
        split = remove_switches(scaled, opt.k, verify=True)
    else:
        split = trivial_split(scaled, opt.k)
    classes = pack_arborescences(split.graph, opt.k)
    verify_packing(split.graph, opt.k, classes)


# ---------------------------------------------------------------------- #
# fixed-k (§2.4)
# ---------------------------------------------------------------------- #

def test_fixed_k_bounds():
    g = torus_2d(2, 2)   # full optimum needs k=2
    full = solve_optimality(g)
    r1 = solve_fixed_k(g, 1)
    # k=1 can't beat the true optimum, and Theorem 15 bounds the gap
    assert r1.runtime_factor >= full.inv_x_star
    assert r1.runtime_factor <= full.inv_x_star + Fraction(1, 1 * min(
        g.cap.values()))
    rk = solve_fixed_k(g, full.k)
    assert rk.runtime_factor == full.inv_x_star
