"""Pallas kernel validation in interpret mode: shape/dtype sweeps against
the pure-jnp oracles in repro.kernels.ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunk_accum import chunk_accum
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import chunk_accum_reference, mha_reference

KEY = jax.random.PRNGKey(7)


def qkv(b, h, hkv, s, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, s, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("shape", [
    (1, 2, 2, 128, 16),    # MHA
    (2, 4, 2, 256, 32),    # GQA
    (1, 4, 1, 128, 64),    # MQA
    (2, 2, 2, 512, 16),    # longer seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(shape, dtype):
    b, h, hkv, s, d = shape
    q, k, v = qkv(b, h, hkv, s, d, dtype)
    got = flash_attention(q, k, v, block_q=64, block_kv=64, interpret=True)
    ref = mha_reference(q, k, v)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


@pytest.mark.parametrize("kwargs", [
    dict(causal=False),
    dict(causal=True, window=64),
    dict(causal=True, prefix_len=32),
    dict(causal=True, logit_cap=50.0),
    dict(causal=True, window=96, logit_cap=30.0),
])
def test_flash_attention_mask_variants(kwargs):
    q, k, v = qkv(2, 4, 2, 256, 32, jnp.float32)
    got = flash_attention(q, k, v, block_q=64, block_kv=64, interpret=True,
                          **kwargs)
    ref = mha_reference(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_flash_attention_block_invariance():
    q, k, v = qkv(1, 2, 2, 256, 32, jnp.float32)
    a = flash_attention(q, k, v, block_q=32, block_kv=64, interpret=True)
    b = flash_attention(q, k, v, block_q=128, block_kv=128, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("shape", [(8, 512), (16, 1024), (32, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_chunk_accum_sweep(shape, dtype):
    n, c = shape
    acc = jax.random.normal(KEY, (n, c), jnp.float32)
    upd = jax.random.normal(jax.random.PRNGKey(3), (n, c)).astype(dtype)
    got = chunk_accum(acc, upd, interpret=True)
    ref = chunk_accum_reference(acc, upd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)


def test_flash_hook_in_models():
    """The kernel can be registered as the models' attention impl and
    produces the same result as the jnp path."""
    from repro.kernels.ops import enable_flash_in_models, \
        disable_flash_in_models
    from repro.models.attention import attend, MaskSpec
    b, s, h, hkv, d = 1, 128, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    pos = jnp.arange(s)
    base = attend(q, k, v, pos, pos, MaskSpec(causal=True))
    enable_flash_in_models()
    try:
        got = attend(q, k, v, pos, pos, MaskSpec(causal=True))
    finally:
        disable_flash_in_models()
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), atol=1e-5)


# ---------------------------------------------------------------------- #
# SSD intra-chunk kernel
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("shape", [(2, 64, 8, 16, 32), (3, 128, 16, 32, 32),
                                   (1, 256, 32, 16, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunk_kernel(shape, dtype):
    from repro.kernels.ssd_scan import ssd_chunk_intra
    from repro.kernels.ref import ssd_chunk_reference
    bh, s, p, n, q = shape
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (bh, s, p)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, s))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (bh,)))
    b = jax.random.normal(ks[3], (bh, s, n)).astype(dtype)
    c = jax.random.normal(ks[4], (bh, s, n)).astype(dtype)
    y, states = ssd_chunk_intra(x, dt, a, b, c, chunk=q, interpret=True)
    assert states.shape == (bh, s // q, p, n)
    atol = 1e-4 if dtype == jnp.float32 else 0.35
    for i in range(bh):
        for j in range(s // q):
            sl = slice(j * q, (j + 1) * q)
            ref = ssd_chunk_reference(
                x[i, sl].astype(jnp.float32)[:, None, :],
                dt[i, sl].astype(jnp.float32)[:, None],
                a[i][None], b[i, sl].astype(jnp.float32),
                c[i, sl].astype(jnp.float32))[:, 0, :]
            np.testing.assert_allclose(
                np.asarray(y[i, sl], np.float32), np.asarray(ref),
                atol=atol, rtol=0.1)
