"""HLO counting: trip-adjusted flops/bytes/collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_count import count, parse_hlo
from repro.analysis.roofline import RooflineTerms, model_flops_for
from repro.configs import get_config, shape_by_name


def test_scan_trip_adjustment():
    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out

    def unrolled(x, w):
        for _ in range(8):
            x = x @ w
        return x

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    fs = count(jax.jit(scanned).lower(x, w).compile().as_text())["flops"]
    fu = count(jax.jit(unrolled).lower(x, w).compile().as_text())["flops"]
    assert fs == fu == 2 * 128 ** 3 * 8


def test_remat_grad_flops():
    def loss(w, x):
        def body(c, _):
            return jax.nn.relu(c @ w), None
        out, _ = jax.lax.scan(jax.checkpoint(body), x, None, length=4)
        return out.sum()
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    f = count(jax.jit(jax.grad(loss)).lower(w, x).compile().as_text())["flops"]
    # fwd 4 + remat 4 + bwd 8 = 16 matmuls
    assert f == 2 * 64 ** 3 * 16


def test_roofline_terms():
    t = RooflineTerms(arch="x", shape="train_4k", mesh="16x16", chips=256,
                      hlo_flops=197e12, hlo_bytes=819e9,
                      collective_bytes={"all-reduce": int(100e9)},
                      model_flops=197e12 * 256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(1.0)
    assert t.useful_flops_ratio == pytest.approx(1.0)


def test_model_flops_kinds():
    cfg = get_config("qwen3-8b")
    tr = model_flops_for(cfg, shape_by_name("train_4k"))
    pf = model_flops_for(cfg, shape_by_name("prefill_32k"))
    dc = model_flops_for(cfg, shape_by_name("decode_32k"))
    assert tr == 3 * pf            # 6ND vs 2ND at equal token count
    assert dc < pf / 1000          # decode: one token per sequence


def test_moe_active_flops():
    moe = get_config("mixtral-8x7b")
    dense_equiv = moe.param_count()
    active = moe.active_param_count()
    assert active < dense_equiv / 2     # top-2 of 8 experts
