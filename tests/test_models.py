"""Per-architecture smoke tests (reduced configs): one train step + one
forward on CPU asserting output shapes and finiteness, prefill/decode
consistency, SSD chunked-vs-recurrent equality, ring-buffer cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import build_model
from repro.models.attention import (MaskSpec, _blockwise_attend,
                                    _direct_attend)
from repro.models.ssm import ssd_chunked, ssd_reference

RNG = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=32):
    batch = {"tokens": jax.random.randint(RNG, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patch_embed"] = jax.random.normal(
            RNG, (b, cfg.num_image_tokens, cfg.d_model)) * 0.02
    if cfg.family == "audio":
        batch["audio_embed"] = jax.random.normal(
            RNG, (b, cfg.encoder_seq, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_train_step(name):
    """Reduced config: forward + loss + grad, no NaNs, loss shape ()."""
    cfg = reduced_config(name)
    model = build_model(cfg, remat=True)
    params = model.init(RNG)
    batch = make_batch(cfg)
    loss, token_loss = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), name
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_prefill_decode_shapes(name):
    cfg = reduced_config(name)
    model = build_model(cfg)
    params = model.init(RNG)
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    state = model.init_decode_state(b, 64)
    state, logits = jax.jit(model.prefill)(params, batch, state)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    prefix = cfg.num_image_tokens if cfg.family == "vlm" else 0
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, state = jax.jit(model.decode_step)(
        params, tok, state, jnp.asarray(s + prefix, jnp.int32))
    assert logits2.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("name", ["qwen3-8b", "mixtral-8x7b", "gemma2-2b",
                                  "mamba2-780m", "zamba2-1.2b",
                                  "whisper-medium", "paligemma-3b"])
def test_decode_consistent_with_prefill(name):
    """decode_step(t_S) logits must equal prefill over [0..S] logits.
    MoE archs need drop-free capacity: prefill and decode dispatch
    separately, so capacity drops would (correctly) differ."""
    cfg = reduced_config(name, capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(RNG)
    b, s = 2, 17
    batch = make_batch(cfg, b, s + 1)
    short = dict(batch, tokens=batch["tokens"][:, :s])

    state = model.init_decode_state(b, 64)
    state, _ = model.prefill(params, short, state)
    prefix = cfg.num_image_tokens if cfg.family == "vlm" else 0
    tok = batch["tokens"][:, s:s + 1]
    logits_dec, _ = model.decode_step(
        params, tok, state, jnp.asarray(s + prefix, jnp.int32))

    state2 = model.init_decode_state(b, 64)
    _, logits_full = model.prefill(params, batch, state2)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full),
                               atol=2e-3, rtol=2e-3)


def test_ring_buffer_cache_matches_full_cache():
    """Sliding-window arch: a window-sized ring cache must produce the same
    decode logits as an unbounded cache."""
    cfg = reduced_config("mixtral-8x7b", sliding_window=24,
                         capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(RNG)
    b, s, steps = 1, 30, 8
    batch = make_batch(cfg, b, s)

    # ring cache: init_decode_state bounds it at window=24
    st_ring = model.init_decode_state(b, 256)
    assert st_ring["kv"][0].shape[2] == 24
    st_ring, lg = model.prefill(params, batch, st_ring)
    # full cache: force an unbounded one by lying about the window
    import repro.models.transformer as T
    full = (jnp.zeros((cfg.num_layers, b, 256,
                       cfg.num_kv_heads, cfg.hd)),
            jnp.zeros((cfg.num_layers, b, 256,
                       cfg.num_kv_heads, cfg.hd)))
    st_full = {"kv": full}
    st_full, lg2 = model.prefill(params, batch, st_full)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg2),
                               atol=2e-3, rtol=2e-3)
    tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
    for i in range(steps):
        idx = jnp.asarray(s + i, jnp.int32)
        l1, st_ring = model.decode_step(params, tok, st_ring, idx)
        l2, st_full = model.decode_step(params, tok, st_full, idx)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   atol=2e-3, rtol=2e-3)
        tok = jnp.argmax(l1[:, -1], -1)[:, None].astype(jnp.int32)


# ---------------------------------------------------------------------- #
# MoE dispatch edge cases
# ---------------------------------------------------------------------- #

def _tiny_moe_cfg(**kw):
    from repro.models.common import ModelConfig
    return ModelConfig(name="tiny-moe", family="moe", num_layers=1,
                       d_model=8, num_heads=2, num_kv_heads=2, d_ff=16,
                       vocab_size=32, num_experts=4, num_experts_per_tok=2,
                       moe_d_ff=16, num_shared_experts=0, **kw)


def test_moe_capacity_overflow_drops_tokens():
    """cap = ceil(8*2*0.1/4) = 1: identical tokens all route to the same
    two experts, so only the first token wins a slot anywhere; every later
    token hits pos >= cap, lands in the overflow slot, and must contribute
    exactly zero."""
    from repro.models.moe import init_moe, moe_forward
    cfg = _tiny_moe_cfg(capacity_factor=0.1)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    one = jax.random.normal(jax.random.PRNGKey(1), (1, 1, cfg.d_model))
    x = jnp.broadcast_to(one, (1, 8, cfg.d_model))
    y, aux = moe_forward(p, cfg, x)
    assert bool(jnp.any(y[0, 0] != 0))
    np.testing.assert_array_equal(np.asarray(y[0, 1:]), 0.0)
    assert bool(jnp.isfinite(aux))
    # drop-free capacity on the same inputs: every (identical) token gets
    # the same expert mix, and the kept token's output is unchanged
    cfg_full = _tiny_moe_cfg(capacity_factor=16.0)
    y_full, _ = moe_forward(p, cfg_full, x)
    np.testing.assert_allclose(
        np.asarray(y_full[0, 1:]),
        np.broadcast_to(np.asarray(y_full[0, :1]), (7, cfg.d_model)),
        atol=1e-6)
    np.testing.assert_allclose(np.asarray(y_full[0, 0]),
                               np.asarray(y[0, 0]), atol=1e-6)


def test_moe_group_fallback_when_tokens_not_divisible():
    """set_moe_groups(3) with 8 tokens: 8 % 3 != 0 must silently fall back
    to one group and reproduce the ungrouped forward bit-for-bit."""
    from repro.models import moe as moe_mod
    cfg = _tiny_moe_cfg(capacity_factor=2.0)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, cfg.d_model))
    base, aux_base = moe_mod.moe_forward(p, cfg, x)
    try:
        moe_mod.set_moe_groups(3)
        assert moe_mod.get_moe_groups() == 3
        y, aux = moe_mod.moe_forward(p, cfg, x)
    finally:
        moe_mod.set_moe_groups(1)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(base))
    np.testing.assert_array_equal(np.asarray(aux), np.asarray(aux_base))


# ---------------------------------------------------------------------- #
# SSD property tests
# ---------------------------------------------------------------------- #

def _check_ssd_chunked_equals_recurrence(seed, chunk, b, h):
    key = jax.random.PRNGKey(seed)
    s, p, n = 2 * chunk, 8, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (b, s, n))
    cc = jax.random.normal(ks[4], (b, s, n))
    y1, f1 = ssd_chunked(x, dt, a, bb, cc, chunk)
    y2, f2 = ssd_reference(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("seed,chunk,b,h", [
    (0, 16, 1, 4), (1, 32, 2, 8), (2, 64, 1, 8), (3, 16, 2, 4),
])
def test_ssd_chunked_equals_recurrence(seed, chunk, b, h):
    _check_ssd_chunked_equals_recurrence(seed, chunk, b, h)


def test_ssd_chunked_equals_recurrence_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=10, deadline=None)
    @hypothesis.given(st.integers(0, 2 ** 31 - 1),
                      st.sampled_from([16, 32, 64]),
                      st.sampled_from([1, 2]), st.sampled_from([4, 8]))
    def check(seed, chunk, b, h):
        _check_ssd_chunked_equals_recurrence(seed, chunk, b, h)

    check()


# ---------------------------------------------------------------------- #
# blockwise attention property tests
# ---------------------------------------------------------------------- #

def _check_blockwise_matches_direct(seed, window, causal, prefix, cap):
    if not causal:
        window = None
    key = jax.random.PRNGKey(seed)
    b, s, h, hkv, d = 2, 256, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    pos = jnp.arange(s)
    spec = MaskSpec(causal=causal, window=window, prefix_len=prefix)
    ref = _direct_attend(q, k, v, pos, pos, spec, cap)
    got = _blockwise_attend(q, k, v, pos, pos, spec, cap,
                            block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("seed,window,causal,prefix,cap", [
    (0, None, True, 0, None),
    (1, 64, True, 16, None),
    (2, 128, True, 0, 30.0),
    (3, None, False, 16, None),
    (4, None, True, 16, 30.0),
])
def test_blockwise_matches_direct(seed, window, causal, prefix, cap):
    _check_blockwise_matches_direct(seed, window, causal, prefix, cap)


def test_blockwise_matches_direct_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=12, deadline=None)
    @hypothesis.given(st.integers(0, 2 ** 31 - 1),
                      st.sampled_from([None, 64, 128]),
                      st.booleans(),
                      st.sampled_from([0, 16]),
                      st.sampled_from([None, 30.0]))
    def check(seed, window, causal, prefix, cap):
        _check_blockwise_matches_direct(seed, window, causal, prefix, cap)

    check()
