"""Staged compiler pipeline: stage-by-stage equivalence with the compile_*
entry points, golden byte-identity through explicit stage calls, family
compilation vs per-kind compilation on random topologies, per-stage
instrumentation, and the v3 cache schema (stats sidecar, flock'd index)."""
import json
import multiprocessing
from pathlib import Path

import numpy as np
import pytest

from repro.cache import (ScheduleCache, SMOKE_NAMES, allreduce_to_json,
                         run_sweep, schedule_to_json, stats_to_payload)
from repro.core import (CollectivePlan, CompileStats, PlanError,
                        compile_allgather, compile_allreduce,
                        compile_broadcast, compile_family, compile_plan,
                        compile_reduce, compile_reduce_scatter, plan_for,
                        simulate_allgather)
from repro.core import plan as plan_mod
from repro.core.graph import DiGraph
from repro.topo import bidir_ring, dragonfly, fig1a, ring, two_cluster_switch

GOLDEN_DIR = Path(__file__).parent / "golden"


def _random_eulerian(seed, n_compute=4, n_switch=1, max_cap=4):
    """Random Eulerian digraph from random directed cycles (cycle sums are
    always Eulerian; the base cycle keeps everything connected)."""
    rng = np.random.default_rng(seed)
    n = n_compute + n_switch
    edges = {}
    nodes = list(range(n))
    cycles = [nodes[:]]
    for _ in range(int(rng.integers(1, 5))):
        k = int(rng.integers(2, n + 1))
        cycles.append(list(rng.choice(n, size=k, replace=False)))
    for cyc in cycles:
        cap = int(rng.integers(1, max_cap + 1))
        for i in range(len(cyc)):
            u, v = int(cyc[i]), int(cyc[(i + 1) % len(cyc)])
            if u != v:
                edges[(u, v)] = edges.get((u, v), 0) + cap
    return DiGraph(n, frozenset(range(n_compute)), edges, f"rand{seed}")


# ---------------------------------------------------------------------- #
# staged pipeline == monolith entry points
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("fname,make,compiler", [
    ("fig1a.allgather.p8.json", fig1a,
     lambda g: ("allgather", dict(num_chunks=8))),
    ("bring8.allgather.p8.json", lambda: bidir_ring(8),
     lambda g: ("allgather", dict(num_chunks=8))),
    ("two_cluster_3x6.allgather.p8.json",
     lambda: two_cluster_switch(3, 6, 2),
     lambda g: ("allgather", dict(num_chunks=8))),
    ("fig1a.broadcast.r0.p8.json", fig1a,
     lambda g: ("broadcast", dict(num_chunks=8, root=0))),
    ("bring8.reduce.r0.p8.json", lambda: bidir_ring(8),
     lambda g: ("reduce", dict(num_chunks=8, root=0))),
    ("fig1a.alltoall.p1.json", fig1a,
     lambda g: ("alltoall", dict(num_chunks=1))),
])
def test_golden_bytes_through_explicit_stages(fname, make, compiler):
    """Running the five stages by hand reproduces every checked-in golden
    byte for byte — the refactor is semantics-preserving at the artifact
    level, not merely runtime-equivalent."""
    g = make()
    kind, kwargs = compiler(g)
    plan = plan_for(kind, g, **kwargs)
    plan = plan_mod.rounds(plan_mod.pack(plan_mod.split(plan_mod.solve(plan))))
    sched = plan_mod.emit(plan)
    assert schedule_to_json(sched) == (GOLDEN_DIR / fname).read_text()


def test_stages_are_pure():
    """Each stage returns a new plan and leaves its input untouched."""
    p0 = plan_for("allgather", fig1a(), num_chunks=4)
    p1 = plan_mod.solve(p0)
    assert p0.opt is None and p1.opt is not None
    assert p0.stats.stages == [] and len(p1.stats.stages) == 1
    p2 = plan_mod.split(p1)
    assert p1.split is None and p2.split is not None
    p3 = plan_mod.pack(p2)
    assert p2.classes is None and p3.classes is not None
    p4 = plan_mod.rounds(p3)
    assert p3.rounds is None and p4.rounds is not None
    # stage products shared by reference but the earlier plans unchanged
    assert p1.opt is p4.opt
    sched = plan_mod.emit(p4)
    assert schedule_to_json(sched) == schedule_to_json(
        compile_allgather(fig1a(), num_chunks=4))


def test_stage_order_enforced():
    p = plan_for("allgather", ring(4), num_chunks=4)
    with pytest.raises(PlanError, match="needs stage product"):
        plan_mod.pack(p)
    p = plan_mod.solve(p)
    with pytest.raises(PlanError, match="already ran"):
        plan_mod.solve(p)
    with pytest.raises(PlanError, match="needs stage product"):
        plan_mod.rounds(p)


def test_plan_for_validates():
    with pytest.raises(PlanError, match="unknown plan kind"):
        plan_for("allreduce", ring(4))        # composite: use compile_family
    with pytest.raises(PlanError, match="explicit root"):
        plan_for("broadcast", ring(4))
    with pytest.raises(PlanError, match="no fixed-k"):
        plan_for("reduce", ring(4), root=0, fixed_k=2)


def test_compile_stats_recorded():
    sched = compile_allgather(fig1a(), num_chunks=8)
    cs = sched.compile_stats
    assert isinstance(cs, CompileStats)
    assert [s.stage for s in cs.stages] == ["solve", "split", "pack",
                                            "rounds"]
    assert all(s.wall_time_s >= 0 for s in cs.stages)
    assert cs.stages[0].meta["k"] == 1
    assert cs.stages[2].meta["classes"] == len(sched.classes)
    assert cs.total_time_s == pytest.approx(
        sum(cs.stage_seconds().values()))
    # stage 5: lowering records itself idempotently on the artifact
    from repro.comms import compile_program
    compile_program(sched)
    compile_program(sched)
    stages = [s.stage for s in sched.compile_stats.stages]
    assert stages == ["solve", "split", "pack", "rounds", "lower"]
    rt = CompileStats.from_dict(sched.compile_stats.to_dict())
    assert rt.stage_seconds() == sched.compile_stats.stage_seconds()


def test_allreduce_shares_solve_between_halves():
    """The AG half adopts the RS half's §2.1 solution (Eulerian transpose
    symmetry) instead of re-running the binary search."""
    ar = compile_allreduce(dragonfly(), num_chunks=4)
    rs_solve = ar.rs.compile_stats.stage_seconds()
    ag_solve = [s for s in ar.ag.compile_stats.stages if s.stage == "solve"]
    assert ag_solve[0].meta.get("shared") == "transpose"
    assert "shared" not in ar.rs.compile_stats.stages[0].meta
    assert ar.rs.opt == ar.ag.opt
    assert set(rs_solve) == {"solve", "split", "pack", "rounds"}


# ---------------------------------------------------------------------- #
# compile_family == per-kind compile_* (property, random topologies)
# ---------------------------------------------------------------------- #

FAMILY_SEEDS = list(range(14)) + [(s, 0) for s in range(8)]


@pytest.mark.parametrize("seed", FAMILY_SEEDS)
def test_family_matches_per_kind_on_random_topologies(seed):
    """compile_family's stage sharing is byte-exact vs the per-kind entry
    points across 22 random Eulerian topologies (14 switched + 8 pure
    direct-connect)."""
    if isinstance(seed, tuple):
        g = _random_eulerian(seed[0], n_compute=5, n_switch=0)
    else:
        g = _random_eulerian(seed, n_compute=4, n_switch=seed % 3)
    root = min(g.compute)
    fam = compile_family(
        g, kinds=("allgather", "reduce_scatter", "allreduce", "broadcast",
                  "reduce"), num_chunks=4, root=root)
    assert schedule_to_json(fam["allgather"]) == \
        schedule_to_json(compile_allgather(g, num_chunks=4))
    assert schedule_to_json(fam["reduce_scatter"]) == \
        schedule_to_json(compile_reduce_scatter(g, num_chunks=4))
    assert allreduce_to_json(fam["allreduce"]) == \
        allreduce_to_json(compile_allreduce(g, num_chunks=4))
    assert schedule_to_json(fam["broadcast"]) == \
        schedule_to_json(compile_broadcast(g, root=root, num_chunks=4))
    assert schedule_to_json(fam["reduce"]) == \
        schedule_to_json(compile_reduce(g, root=root, num_chunks=4))


def test_family_fixed_k_matches_per_kind():
    g = _random_eulerian(3, n_compute=5, n_switch=0)
    fam = compile_family(g, kinds=("allgather", "allreduce"), num_chunks=4,
                         fixed_k=1)
    assert schedule_to_json(fam["allgather"]) == \
        schedule_to_json(compile_allgather(g, num_chunks=4, fixed_k=1))
    assert allreduce_to_json(fam["allreduce"]) == \
        allreduce_to_json(compile_allreduce(g, num_chunks=4, fixed_k=1))


def test_family_validates_kinds():
    with pytest.raises(PlanError, match="unknown collective kinds"):
        compile_family(ring(4), kinds=("allgather", "gatherscatter"))


def test_family_timings_are_marginal():
    """`timings` charges shared stage work to the kind that triggered it:
    every requested kind gets an entry, and allreduce (which reuses the
    packed AG/RS products) is charged (near-)nothing."""
    timings = {}
    compile_family(fig1a(), kinds=("allgather", "reduce_scatter",
                                   "allreduce"), num_chunks=4,
                   timings=timings)
    assert set(timings) == {"allgather", "reduce_scatter", "allreduce"}
    assert all(t >= 0 for t in timings.values())
    assert timings["allreduce"] < timings["allgather"]


def test_family_packed_out_rechunks_byte_identically():
    """Re-running only rounds+emit on a packed plan at a larger P (the
    sweep's P >= depth path) equals a from-scratch compile at that P."""
    import dataclasses
    packed = {}
    compile_family(fig1a(), kinds=("allgather",), num_chunks=4,
                   packed_out=packed)
    p = dataclasses.replace(packed["allgather"], num_chunks=16)
    redone = plan_mod.emit(plan_mod.rounds(p))
    assert schedule_to_json(redone) == schedule_to_json(
        compile_allgather(fig1a(), num_chunks=16))


# ---------------------------------------------------------------------- #
# cache schema v3: stats sidecar, advisory index, flock'd writers
# ---------------------------------------------------------------------- #

def test_cache_replays_compile_stats(tmp_path):
    c = ScheduleCache(tmp_path)
    sched = c.allgather(fig1a(), num_chunks=4)
    want = sched.compile_stats.stage_seconds()
    assert c.stats_path_for(c.key("allgather", fig1a(), 4)).exists()
    fresh = ScheduleCache(tmp_path)
    hit = fresh.allgather(fig1a(), num_chunks=4)
    assert fresh.stats.hits == 1
    assert hit.compile_stats is not None
    assert hit.compile_stats.stage_seconds() == want
    # allreduce sidecar carries both halves
    ar = ScheduleCache(tmp_path).allreduce(ring(4), num_chunks=4)
    back = ScheduleCache(tmp_path).allreduce(ring(4), num_chunks=4)
    assert back.rs.compile_stats is not None
    assert back.ag.compile_stats is not None
    assert stats_to_payload(back)["rs"] == stats_to_payload(ar)["rs"]


def test_cache_index_tracks_entries(tmp_path):
    c = ScheduleCache(tmp_path)
    c.allgather(ring(4), num_chunks=4)
    c.broadcast(bidir_ring(5), root=0, num_chunks=4)
    idx = c.index()
    assert sorted(idx) == c.entries()
    for key, info in idx.items():
        assert info["kind"] == key.split("-", 1)[0]
        assert info["bytes"] == c.path_for(key).stat().st_size
    # rebuild reconstructs the same thing from the directory
    (tmp_path / ".index").unlink()
    assert sorted(c.rebuild_index()) == c.entries()
    c.clear()
    assert c.index() == {} and c.entries() == []
    assert list(tmp_path.glob("*.stats")) == []


def test_cache_eviction_and_prune_drop_sidecars(tmp_path):
    probe = ScheduleCache(tmp_path / "probe")
    probe.allgather(ring(4), num_chunks=4)
    cap = probe.size_bytes() + 10
    c = ScheduleCache(tmp_path / "lru", max_bytes=cap)
    c.allgather(ring(4), num_chunks=4)
    c.allgather(ring(5), num_chunks=4)           # evicts ring4
    assert c.stats.evictions == 1
    stems = {p.stem for p in (tmp_path / "lru").glob("*.json")}
    sidecars = {p.stem for p in (tmp_path / "lru").glob("*.stats")}
    assert sidecars == stems                     # no orphan sidecars
    assert sorted(c.index()) == sorted(stems)
    stale = ScheduleCache(tmp_path / "stale", compiler_fp="deadbeef00000000")
    stale.allgather(ring(4), num_chunks=4)
    cur = ScheduleCache(tmp_path / "stale")
    assert cur.prune_stale() == 1
    assert list((tmp_path / "stale").glob("*.stats")) == []


def _writer(args):
    root, n = args
    cache = ScheduleCache(root)
    sched = cache.allgather(ring(n), num_chunks=4)
    return sched.claimed_runtime is not None


def test_concurrent_cache_writers(tmp_path):
    """Several processes writing the same cache directory at once: every
    artifact lands, the flock'd index is consistent, and everything
    replays."""
    sizes = [4, 5, 6, 7]
    # spawn, not fork: other tests load JAX (multithreaded) in this process
    ctx = multiprocessing.get_context("spawn")
    with ctx.Pool(4) as pool:
        results = pool.map(_writer, [(str(tmp_path), n) for n in sizes])
    assert all(results)
    c = ScheduleCache(tmp_path)
    assert len(c.entries()) == len(sizes)
    assert sorted(c.index()) == c.entries()
    for n in sizes:
        sched = c.allgather(ring(n), num_chunks=4)
        assert simulate_allgather(sched).sim_time == sched.claimed_runtime
    assert c.stats.misses == 0


# ---------------------------------------------------------------------- #
# sweep v3: per-stage timings + fixed-k rows
# ---------------------------------------------------------------------- #

def test_sweep_rows_carry_stage_timings(tmp_path):
    doc = run_sweep(names=("ring8",), jobs=1,
                    collectives=("allgather", "allreduce"),
                    out_path=str(tmp_path / "bench.json"))
    assert doc["version"] == 7
    assert doc["fixed_k"] is None
    by_kind = {e["kind"]: e for e in doc["entries"]}
    for e in doc["entries"]:
        assert e["fixed_k"] is None
        stats = e["compile_stats"]
        # v6: per-stage list rows in pipeline order, seconds + counters
        assert {r["stage"] for r in stats} == {"solve", "split", "pack",
                                               "rounds"}
        assert all(r["seconds"] >= 0 and r["probes"] >= 0
                   and r["augments"] >= 0 for r in stats)
        # oracle-engine work counters ride on every row (= column sums)
        assert e["oracle_probes"] == sum(r["probes"] for r in stats)
        assert e["oracle_augments"] == sum(r["augments"] for r in stats)
        assert isinstance(e["oracle_probes"], int)
    # compile_time_s is the kind's *marginal* family time: the first kind
    # pays its own stages in full...
    ag = by_kind["allgather"]
    assert (sum(r["seconds"] for r in ag["compile_stats"])
            <= ag["compile_time_s"] + 1e-3)
    # ...while allreduce reuses the packed products of its siblings — its
    # marginal time is (near-)free even though its stats report the shared
    # stages that produced the artifact
    ar = by_kind["allreduce"]
    assert ar["compile_time_s"] < ag["compile_time_s"] + 0.1
    assert ar["oracle_probes"] >= ag["oracle_probes"]  # stats of both halves
    on_disk = json.loads((tmp_path / "bench.json").read_text())
    assert on_disk["entries"][0]["compile_stats"][0]["stage"] == "solve"


def test_sweep_fixed_k_rows(tmp_path):
    doc = run_sweep(names=SMOKE_NAMES, jobs=1, fixed_k=1,
                    out_path=str(tmp_path / "bench_k1.json"))
    assert doc["fixed_k"] == 1
    assert list(doc["collectives"]) == ["allgather", "reduce_scatter",
                                        "allreduce", "alltoall"]
    assert doc["num_entries"] + len(doc["skipped"]) == 4 * len(SMOKE_NAMES)
    for e in doc["entries"]:
        assert e["fixed_k"] == 1
        assert e["k"] == 1
        assert e["achieved_over_claimed"] == "1"


def test_sweep_fixed_k_rejects_rooted_kinds():
    with pytest.raises(KeyError, match="rooted"):
        run_sweep(names=("ring8",), fixed_k=1, collectives=("broadcast",))
