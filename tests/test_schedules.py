"""Schedule compilation + simulation: correctness (verifier) and bandwidth
optimality (ratio -> 1 with chunk count) across the topology zoo — the
executable form of the paper's main theorem."""
from fractions import Fraction

import numpy as np
import pytest

from repro.core import (broadcast_lambda, broadcast_root_lb,
                        compile_allgather, compile_allreduce,
                        compile_broadcast, compile_reduce,
                        compile_reduce_scatter, cut_traffic,
                        reduce_root_lb, rs_ag_allreduce_runtime,
                        re_bc_allreduce_runtime, simulate_allgather,
                        simulate_allreduce, simulate_broadcast,
                        simulate_reduce, simulate_reduce_scatter,
                        solve_optimality, theorem19_rs_ag_optimal)
from repro.core.graph import DiGraph
from repro.core.schedule import Send
from repro.topo import (bcube, bidir_ring, dgx_box, dragonfly, fat_tree,
                        fig1a, fully_connected, hypercube, mesh_of_dgx, ring,
                        star_switch, torus_2d)

ZOO = [fig1a, lambda: ring(6), lambda: bidir_ring(5),
       lambda: torus_2d(3, 3), fat_tree, dragonfly, dgx_box,
       lambda: star_switch(5), lambda: fully_connected(4),
       lambda: hypercube(3), lambda: bcube(2), lambda: mesh_of_dgx(2, 2, 2)]


@pytest.mark.parametrize("make", ZOO)
def test_allgather_verified_and_near_optimal(make):
    g = make()
    sched = compile_allgather(g, num_chunks=16, verify=True)
    rep = simulate_allgather(sched)           # verifier runs inside
    assert rep.ratio < 2.0
    rep64 = simulate_allgather(compile_allgather(g, num_chunks=64))
    assert rep64.sim_time <= rep.sim_time
    assert rep64.ratio < 1.2, f"{g.name}: ratio {rep64.ratio}"


@pytest.mark.parametrize("make", ZOO)
def test_reduce_scatter_verified(make):
    g = make()
    rep = simulate_reduce_scatter(compile_reduce_scatter(g, num_chunks=16))
    assert rep.ratio < 2.0


@pytest.mark.parametrize("make", ZOO)
def test_rs_ag_duality(make):
    """Appendix B / Zhao et al. duality: the compiled reduce-scatter on G is
    exactly the allgather compiled on G^T with every send reversed and the
    round order flipped — for every zoo topology."""
    g = make()
    rs = compile_reduce_scatter(g, num_chunks=8)
    ag = compile_allgather(g.transpose(), num_chunks=8)
    assert rs.opt == ag.opt
    assert rs.dstar.cap == ag.dstar.transpose().cap
    assert rs.class_slot_offset == ag.class_slot_offset
    want = [[Send(src=s.dst, dst=s.src, root=s.root, slot=s.slot, cls=s.cls)
             for s in rnd] for rnd in reversed(ag.rounds)]
    assert rs.rounds == want
    # both sides claim the same exact optimal bound
    assert rs.lb_runtime_factor() == ag.lb_runtime_factor()


@pytest.mark.parametrize("make", [fig1a, lambda: ring(5), dragonfly])
def test_allreduce_verified(make):
    g = make()
    rep = simulate_allreduce(compile_allreduce(g, num_chunks=16))
    assert rep.ratio < 2.0


def test_pipeline_convergence_fig1a():
    """§1.3: step-based (P=1) cannot be optimal; pipelining converges."""
    g = fig1a()
    ratios = [simulate_allgather(compile_allgather(g, num_chunks=p)).ratio
              for p in (1, 4, 16, 64)]
    assert ratios[0] > 1.5                       # one-shot schedule is poor
    assert all(a >= b for a, b in zip(ratios, ratios[1:]))
    assert ratios[-1] < 1.03


def test_minimality_on_bottleneck_cut():
    """Requirement (b) of §1.3: only (M/N)|S*∩Vc| crosses the cut."""
    g = fig1a()
    rep = simulate_allgather(compile_allgather(g, num_chunks=16))
    cluster1 = {0, 1, 2, 3, 9}
    assert cut_traffic(rep, cluster1) == Fraction(4, 8)


def test_exact_optimality_ring():
    """The unidirectional ring allgather hits the bound exactly."""
    rep = simulate_allgather(compile_allgather(ring(8), num_chunks=16))
    assert rep.sim_time == rep.lb_time


def test_rs_ag_beats_re_bc():
    """Appendix B: RS+AG strictly better than reduce+broadcast."""
    for make in (fig1a, lambda: ring(6), dragonfly):
        g = make()
        assert rs_ag_allreduce_runtime(g) < re_bc_allreduce_runtime(g)
    # fig1a: exactly 2x (paper's example)
    g = fig1a()
    assert re_bc_allreduce_runtime(g) == 2 * rs_ag_allreduce_runtime(g)


def test_theorem19_fig1a():
    """fig1a satisfies condition (a): |S*∩Vc| = N/2 -> RS+AG optimal."""
    assert theorem19_rs_ag_optimal(fig1a()) is not None


def test_broadcast_runtime():
    g = bidir_ring(6)
    sched = compile_broadcast(g, root=0, num_chunks=64)
    rep = simulate_broadcast(sched)
    assert rep.ratio < 1.15


@pytest.mark.parametrize("make", ZOO)
def test_broadcast_verified_across_zoo(make):
    """Appendix A on every zoo family — switched topologies go through the
    rooted edge-splitting variant; the verifier replays every chunk and the
    λ(root) bound is met within the pipeline-fill factor."""
    g = make()
    root = min(g.compute)
    sched = compile_broadcast(g, root=root, num_chunks=16, verify=True)
    assert sched.kind == "broadcast" and sched.root == root
    assert sched.k == broadcast_lambda(g, root)
    rep = simulate_broadcast(sched)
    assert rep.lb_time == broadcast_root_lb(g, root)
    # ratio bounded by the §1.3 fill factor (P + depth - 1) / P
    assert rep.ratio <= (16 + sched.depth - 1) / 16 + 1e-9


@pytest.mark.parametrize("make", ZOO)
def test_reduce_verified_across_zoo(make):
    g = make()
    root = min(g.compute)
    sched = compile_reduce(g, root=root, num_chunks=16, verify=True)
    assert sched.kind == "reduce" and sched.root == root
    rep = simulate_reduce(sched)           # contribution-counter replay
    assert rep.lb_time == reduce_root_lb(g, root)
    assert rep.ratio <= (16 + sched.depth - 1) / 16 + 1e-9


@pytest.mark.parametrize("make", ZOO)
def test_reduce_broadcast_duality(make):
    """Reduce on G is exactly broadcast on G^T with every send reversed and
    the round order flipped — the same duality as RS/AG (Appendix B)."""
    g = make()
    root = min(g.compute)
    red = compile_reduce(g, root=root, num_chunks=8)
    bc = compile_broadcast(g.transpose(), root=root, num_chunks=8)
    assert red.opt == bc.opt
    assert red.dstar.cap == bc.dstar.transpose().cap
    want = [[Send(src=s.dst, dst=s.src, root=s.root, slot=s.slot, cls=s.cls)
             for s in rnd] for rnd in reversed(bc.rounds)]
    assert red.rounds == want
    # the two duals meet the same exact bound (Eulerian symmetry)
    assert simulate_reduce(red).sim_time == simulate_broadcast(bc).sim_time


def test_broadcast_converges_to_mincut_bound():
    """Eq (5): as P grows the broadcast runtime -> M/λ(root) exactly."""
    g = fig1a()
    ratios = [simulate_broadcast(
        compile_broadcast(g, root=0, num_chunks=p)).ratio
        for p in (8, 32, 128)]
    assert all(a >= b for a, b in zip(ratios, ratios[1:]))
    assert ratios[-1] < 1.05
    # the bound itself is exact: λ(0) = 4 on fig1a (the 4 NVLink-ish links)
    assert broadcast_root_lb(g, 0) == Fraction(1, broadcast_lambda(g, 0))


def test_fixed_k_schedule_runs():
    g = torus_2d(2, 2)
    sched = compile_allgather(g, num_chunks=8, fixed_k=1)
    rep = simulate_allgather(sched)
    # fixed k=1 on 2x2 torus: U*=2 vs optimal 3/4 -> ratio vs true LB >= 8/6
    assert rep.sim_time >= rep.lb_time
