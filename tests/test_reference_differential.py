"""Differential harness: the optimized oracle engine vs the reference
re-implementations in `repro.core.reference` (straight from the paper's
pseudocode — see that module's docstring for the theorem mapping).

Three layers, each exact (no tolerances):

* oracle values — `FlowNetwork.maxflow` (both substrates) vs
  `reference_maxflow`; `_TheoremEightProber.split_cap` vs
  `reference_split_cap`; `_MuGadget.mu` vs `reference_mu`;
* packing output — `pack_rooted_trees` vs `reference_pack_rooted_trees`
  class-by-class (roots, multiplicities, vertex and edge orders);
* artifacts — compiled schedules byte-identical across maxflow substrates
  (scipy CSR forced vs pure Python forced).

Tier-1 runs the seeded-random cases and a small zoo subset; the `slow`
marker (deselected by default, run by the nightly CI job) extends the
differential sweep over the full topology zoo.

Counter-regression pins ride along here: the split/pack stage meta
``probes`` / ``augments`` on the fig1a and dgx8 fixtures must stay under
pinned ceilings, so an accidental warm-start or caching regression fails
tier-1 instead of only showing up in BENCH wall times.
"""
import random

import pytest

from repro.core import maxflow as maxflow_mod
from repro.core import plan as plan_mod
from repro.core import reference as ref
from repro.core.arborescence import _MuGadget, pack_rooted_trees
from repro.core.edge_split import _TheoremEightProber
from repro.core.maxflow import FlowNetwork
from repro.topo.spec import TopologySpec
from repro.topo.zoo import ZOO_SPECS

# zoo rows the tier-1 (fast) differential subset covers; the slow sweep
# parametrizes over every zoo row instead
FAST_ZOO = ("fig1a", "dgx8", "ring8", "hypercube3")
# full-reference packing is Edmonds-Karp-per-candidate — tractable only on
# rows up to this many compute nodes (larger rows get sampled-µ coverage)
PACK_REF_MAX_COMPUTE = 16
# the substrate byte-identity sweep compiles every family twice, once on
# the *pure-Python* maxflow substrate — tractable up to 64 compute nodes;
# the bigger rows (fattree8p4l4h, torus16x16) are exactly the ones the
# Python substrate can't chew through, which is why they get sampled
# per-oracle differentials instead
BYTES_MAX_COMPUTE = 64
# sampled probes per topology for the large-row µ / split_cap differentials
SAMPLES = 12


def zoo_graph(name):
    return TopologySpec.parse(ZOO_SPECS[name]).build()


def packed_stage_input(g, kind="allgather"):
    """(split graph, k) exactly as the §2.3 pack stage receives them."""
    p = plan_mod.plan_for(kind, g, num_chunks=4, root=None)
    p = plan_mod.split(plan_mod.solve(p))
    return p.split.graph, p.opt.k


def class_signature(classes):
    return [(c.root, c.mult, tuple(c.verts), tuple(c.edges))
            for c in classes]


def random_flow_case(rng):
    n = rng.randint(4, 12)
    edges = []
    for _ in range(rng.randint(n, 4 * n)):
        u, v = rng.sample(range(n), 2)
        edges.append((u, v, rng.randint(1, 20)))
    s, t = rng.sample(range(n), 2)
    limit = rng.choice([None, rng.randint(1, 30)])
    return n, edges, s, t, limit


# ---------------------------------------------------------------------- #
# maxflow primitive
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("substrate", ["scipy", "python"])
def test_maxflow_matches_reference_seeded(substrate, monkeypatch):
    monkeypatch.setattr(maxflow_mod, "FAST_MIN_ENTRIES",
                        0 if substrate == "scipy" else 1 << 30)
    rng = random.Random(1234)
    for _ in range(40):
        n, edges, s, t, limit = random_flow_case(rng)
        net = FlowNetwork(n)
        for u, v, c in edges:
            net.add_edge(u, v, c)
        assert (net.maxflow(s, t, limit=limit)
                == ref.reference_maxflow(edges, s, t, limit=limit))


def test_maxflow_residual_reusable_after_reset(monkeypatch):
    """After reset_flow, a second probe on the same network must equal a
    cold reference solve — on both substrates."""
    rng = random.Random(99)
    for thresh in (0, 1 << 30):
        monkeypatch.setattr(maxflow_mod, "FAST_MIN_ENTRIES", thresh)
        for _ in range(10):
            n, edges, s, t, limit = random_flow_case(rng)
            net = FlowNetwork(n)
            for u, v, c in edges:
                net.add_edge(u, v, c)
            net.maxflow(s, t, limit=limit)
            net.reset_flow()
            assert (net.maxflow(t, s)
                    == ref.reference_maxflow(edges, t, s))


def test_min_flow_from_source_matches_reference():
    """The production Theorem-1/7 oracle is a thresholded bool; compare it
    against the exact reference minimum at thresholds bracketing it."""
    for name in FAST_ZOO:
        g = zoo_graph(name)
        p = plan_mod.solve(plan_mod.plan_for("allgather", g, num_chunks=4,
                                             root=None))
        d = p.scaled
        k = p.opt.k
        exact = ref.reference_min_flow_from_source(d, k)
        for threshold in (exact - 1, exact, exact + 1):
            if threshold < 0:
                continue
            assert (maxflow_mod.min_flow_from_source(d, k, 1, threshold)
                    == (exact >= threshold)), (name, threshold)
        assert ref.reference_feasible(d, k)


# ---------------------------------------------------------------------- #
# Theorem 8 (split) and Theorem 12 (pack step size) oracles
# ---------------------------------------------------------------------- #

def split_cap_triples(g, limit=SAMPLES):
    """Deterministic sample of (u, w, t) Theorem-8 probe triples."""
    out = []
    for w in sorted(g.switches):
        ins = sorted(u for (u, x) in g.cap if x == w and g.cap[(u, w)] > 0)
        outs = sorted(t for (x, t) in g.cap if x == w and g.cap[(w, t)] > 0)
        for u in ins[:3]:
            for t in outs[:3]:
                if u != t:
                    out.append((u, w, t))
    rng = random.Random(7)
    rng.shuffle(out)
    return out[:limit]


def assert_split_cap_matches(name):
    g = zoo_graph(name)
    p = plan_mod.solve(plan_mod.plan_for("allgather", g, num_chunks=4,
                                         root=None))
    sg, k = p.scaled, p.opt.k
    triples = split_cap_triples(sg)
    if not triples:
        pytest.skip(f"{name} is direct-connect (no switch triples)")
    prober = _TheoremEightProber(sg, k)
    for (u, w, t) in triples:
        assert (prober.split_cap(u, w, t)
                == ref.reference_split_cap(sg, k, u, w, t)), (name, u, w, t)


def mu_candidates(dstar, k, limit=SAMPLES):
    """(classes, ci, x, y) probe states sampled from real pack growths: run
    the packer and replay µ probes at the *initial* state of each class
    growth (where every candidate is still open)."""
    from repro.core.arborescence import TreeClass
    nodes = sorted(dstar.compute)
    g = dict(dstar.cap)
    classes = [TreeClass(root=u, mult=k, verts=[u], edges=[])
               for u in nodes]
    out = []
    for ci in range(min(len(classes), 4)):
        x = classes[ci].root
        for y in nodes:
            if y != x and g.get((x, y), 0) > 0:
                out.append((classes, ci, x, y))
    rng = random.Random(11)
    rng.shuffle(out)
    return out[:limit]


def assert_mu_matches(name):
    g = zoo_graph(name)
    dstar, k = packed_stage_input(g)
    cases = mu_candidates(dstar, k)
    gd = dict(dstar.cap)
    for (classes, ci, x, y) in cases:
        gadget = _MuGadget(dstar, gd, classes, ci)
        assert (gadget.mu(x, y)
                == ref.reference_mu(dstar, gd, classes, ci, x, y)), \
            (name, ci, x, y)


def assert_pack_matches(name):
    g = zoo_graph(name)
    dstar, k = packed_stage_input(g)
    demands = {u: k for u in sorted(dstar.compute)}
    assert (class_signature(pack_rooted_trees(dstar, demands))
            == class_signature(ref.reference_pack_rooted_trees(
                dstar, demands))), name


def assert_schedule_bytes_substrate_invariant(name, monkeypatch):
    from repro.cache.serialize import schedule_to_json
    g = zoo_graph(name)

    def compile_pair():
        out = plan_mod.compile_family(g, kinds=("allgather",
                                                "reduce_scatter",
                                                "alltoall"),
                                      num_chunks=4)
        return {k: schedule_to_json(a) for k, a in out.items()}

    monkeypatch.setattr(maxflow_mod, "FAST_MIN_ENTRIES", 0)
    fast = compile_pair()
    monkeypatch.setattr(maxflow_mod, "FAST_MIN_ENTRIES", 1 << 30)
    slow = compile_pair()
    assert fast == slow, name


@pytest.mark.parametrize("name", FAST_ZOO)
def test_split_cap_matches_reference(name):
    assert_split_cap_matches(name)


@pytest.mark.parametrize("name", FAST_ZOO)
def test_mu_matches_reference(name):
    assert_mu_matches(name)


@pytest.mark.parametrize("name", FAST_ZOO)
def test_pack_matches_reference(name):
    assert_pack_matches(name)


@pytest.mark.parametrize("name", FAST_ZOO)
def test_schedule_bytes_substrate_invariant(name, monkeypatch):
    assert_schedule_bytes_substrate_invariant(name, monkeypatch)


def test_pack_matches_reference_seeded_random():
    from test_arborescence import cycle_sum_graph
    for seed in range(4):
        g = cycle_sum_graph(5 + seed, 2, seed)
        dstar, k = packed_stage_input(g)
        demands = {u: k for u in sorted(dstar.compute)}
        assert (class_signature(pack_rooted_trees(dstar, demands))
                == class_signature(ref.reference_pack_rooted_trees(
                    dstar, demands)))


# ---------------------------------------------------------------------- #
# nightly: the full zoo
# ---------------------------------------------------------------------- #

@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(ZOO_SPECS))
def test_zoo_oracles_match_reference_slow(name):
    g = zoo_graph(name)
    dstar, k = packed_stage_input(g)
    if dstar.num_compute <= PACK_REF_MAX_COMPUTE:
        assert_pack_matches(name)
    else:
        assert_mu_matches(name)
    if g.switches:
        assert_split_cap_matches(name)


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(ZOO_SPECS))
def test_zoo_schedule_bytes_substrate_invariant_slow(name, monkeypatch):
    g = zoo_graph(name)
    if g.num_compute > BYTES_MAX_COMPUTE:
        pytest.skip(f"{name}: {g.num_compute} compute nodes — pure-Python "
                    f"substrate compile is intractable; covered by the "
                    f"sampled oracle differentials instead")
    assert_schedule_bytes_substrate_invariant(name, monkeypatch)


# ---------------------------------------------------------------------- #
# counter-regression pins (fig1a / dgx8): ceilings ~1.4x current values
# ---------------------------------------------------------------------- #

COUNTER_CEILINGS = {
    # (fixture, kind, stage): (max probes, max augments)
    ("fig1a", "allgather", "split"): (480, 950),
    ("fig1a", "allgather", "pack"): (60, 220),
    ("fig1a", "reduce_scatter", "split"): (480, 950),
    ("fig1a", "reduce_scatter", "pack"): (60, 220),
    ("dgx8", "allgather", "split"): (240, 1400),
    ("dgx8", "allgather", "pack"): (160, 1020),
    ("dgx8", "reduce_scatter", "split"): (240, 1400),
    ("dgx8", "reduce_scatter", "pack"): (160, 1020),
}


@pytest.mark.parametrize("fixture", ("fig1a", "dgx8"))
def test_oracle_counter_ceilings(fixture):
    g = zoo_graph(fixture)
    for kind in ("allgather", "reduce_scatter"):
        p = plan_mod.plan_for(kind, g, num_chunks=4, root=None)
        p = plan_mod.pack(plan_mod.split(plan_mod.solve(p)))
        by_stage = {s.stage: s.meta for s in p.stats.stages}
        for stage in ("split", "pack"):
            probes = by_stage[stage].get("probes")
            augments = by_stage[stage].get("augments")
            assert probes is not None and augments is not None, \
                f"{fixture}.{kind}.{stage} lost its oracle counters"
            max_p, max_a = COUNTER_CEILINGS[(fixture, kind, stage)]
            assert probes <= max_p, \
                (f"{fixture}.{kind}.{stage} oracle_probes regressed: "
                 f"{probes} > ceiling {max_p}")
            assert augments <= max_a, \
                (f"{fixture}.{kind}.{stage} oracle_augments regressed: "
                 f"{augments} > ceiling {max_a}")
