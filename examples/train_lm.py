"""End-to-end training driver: train a (reduced) assigned architecture for a
few hundred steps on CPU with the full production stack — synthetic data
pipeline, AdamW, checkpointing, fault-tolerant supervisor.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-8b --steps 200
    # or, after `pip install -e .`, plain `python examples/train_lm.py`
"""
import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.models import build_model
from repro.train import (AdamWConfig, TrainConfig, TrainSupervisor,
                         init_train_state, make_train_step)
from repro.train.data import DataConfig, host_batch_slice


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    model = build_model(cfg, remat=True)
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=20,
                                           total_steps=args.steps))
    step_jit = jax.jit(make_train_step(model, tc))
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch,
                    num_image_tokens=cfg.num_image_tokens,
                    encoder_seq=cfg.encoder_seq if cfg.is_encoder_decoder
                    else 0,
                    d_model=cfg.d_model)

    def step_fn(step, state):
        p, o = state
        batch = {k: jnp.asarray(v) for k, v in
                 host_batch_slice(dc, step, 0, args.batch).items()}
        p, o, metrics = step_jit(p, o, batch)
        return (p, o), metrics

    os.makedirs(args.ckpt_dir, exist_ok=True)
    sup = TrainSupervisor(ckpt_dir=args.ckpt_dir, ckpt_every=50)
    state, final = sup.run(state=(params, opt), num_steps=args.steps,
                           step_fn=step_fn, log_every=20)
    print(f"finished at step {final}; "
          f"stragglers flagged: {len(sup.monitor.flagged)}")


if __name__ == "__main__":
    main()
