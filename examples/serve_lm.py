"""Batched serving driver: spin up the engine on a reduced arch and serve a
stream of requests (greedy decoding, ring-buffer KV cache for SWA archs).

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b
    # or, after `pip install -e .`, plain `python examples/serve_lm.py`
"""
import argparse

import jax
import numpy as np

from repro.configs import reduced_config
from repro.models import build_model
from repro.serve import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, batch_size=4, max_len=256)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 20))
        engine.submit(Request(
            uid=i, prompt=rng.integers(1, cfg.vocab_size, plen,
                                       dtype=np.int32),
            max_new_tokens=args.new_tokens))
    for c in engine.run():
        gen = c.tokens[c.prompt_len:]
        print(f"req {c.uid}: prompt {c.prompt_len} tokens -> "
              f"generated {len(gen)}: {gen[:10]}... "
              f"({c.latency_s * 1e3:.0f} ms batch latency)")


if __name__ == "__main__":
    main()
