"""Quickstart: compile a bandwidth-optimal collective schedule for a switch
topology, inspect it, verify it, and execute it on real (host) devices.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from fractions import Fraction

from repro.core import (compile_allgather, simulate_allgather,
                        solve_optimality)
from repro.topo import fig1a, fig1d_ring_unwound
from repro.core.optimality import allgather_inv_xstar


def main() -> None:
    # 1. the paper's Figure 1a topology: 8 compute nodes, 2 clusters,
    #    3 switches; thick links have 10x bandwidth.
    g = fig1a()
    print(g.describe())

    # 2. §2.1: exact optimal bandwidth runtime via maxflow binary search
    opt = solve_optimality(g)
    print(f"\noptimal T_B = (M/N) * {opt.inv_x_star}   (U={opt.U}, k={opt.k})")
    ring = allgather_inv_xstar(fig1d_ring_unwound())
    print(f"TACCL/TACOS-style ring unwinding would give (M/N) * {ring} "
          f"-> {ring / opt.inv_x_star}x worse")

    # 3. §2.2+2.3: edge splitting + arborescence packing + pipelining
    sched = compile_allgather(g, num_chunks=64, verify=True)
    print(f"\nschedule: {sched.describe()}")

    # 4. verify + simulate on the physical topology
    rep = simulate_allgather(sched)
    print(f"simulated: {rep.describe()}")
    assert rep.ratio < 1.05, "should be within 5% of optimal at P=64"
    print("\nOK: schedule is provably correct and bandwidth-optimal.")


if __name__ == "__main__":
    main()
