"""Quickstart: compile a bandwidth-optimal collective schedule for a switch
topology, inspect it, verify it, and execute it on real (host) devices —
all through the repo's two front doors: `repro.topo.spec.TopologySpec`
(declarative topologies) and `repro.api.Collectives` (schedules).

    PYTHONPATH=src python examples/quickstart.py
    # or, after `pip install -e .`, plain `python examples/quickstart.py`
"""
from repro.api import Collectives
from repro.core import simulate_allgather, solve_optimality
from repro.core.optimality import allgather_inv_xstar
from repro.topo import TopologySpec, resolve_topology


def main() -> None:
    # 1. the paper's Figure 1a topology: 8 compute nodes, 2 clusters,
    #    3 switches; thick links have 10x bandwidth.  One spec string
    #    (a zoo name here; "two_cluster:4,10,1" builds the same graph).
    g = resolve_topology("fig1a")
    print(g.describe())

    # 2. §2.1: exact optimal bandwidth runtime via maxflow binary search
    opt = solve_optimality(g)
    print(f"\noptimal T_B = (M/N) * {opt.inv_x_star}   (U={opt.U}, k={opt.k})")
    ring = allgather_inv_xstar(resolve_topology("fig1d"))
    print(f"TACCL/TACOS-style ring unwinding would give (M/N) * {ring} "
          f"-> {ring / opt.inv_x_star}x worse")

    # 3. §2.2+2.3: edge splitting + arborescence packing + pipelining,
    #    through the Collectives facade (pass cache="DIR" to make every
    #    later run replay the artifact instead of compiling)
    coll = Collectives()
    sched = coll.schedule(g, kind="allgather", num_chunks=64, verify=True)
    print(f"\nschedule: {sched.describe()}")

    # 4. verify + simulate on the physical topology
    rep = simulate_allgather(sched)
    print(f"simulated: {rep.describe()}")
    assert rep.ratio < 1.05, "should be within 5% of optimal at P=64"
    print("\nOK: schedule is provably correct and bandwidth-optimal.")

    # 5. declarative what-if: degrade a DCN link, recompile, compare
    degraded = TopologySpec.parse("two_cluster:4,10,2@degrade(0-8,cap=1)")
    print(f"\nwhat-if {degraded}: "
          f"inv_x*={coll.schedule(degraded, num_chunks=64).opt.inv_x_star}")


if __name__ == "__main__":
    main()
