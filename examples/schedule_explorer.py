"""Explore the schedule compiler on any topology: optimality search, edge
splitting, tree packing, chunked pipelining, physical-link loads.

``--topo`` takes a committed zoo row name OR any `TopologySpec` string
(full grammar, transforms included) — no code edit needed for new fabrics:

    PYTHONPATH=src python examples/schedule_explorer.py --topo dragonfly
    PYTHONPATH=src python examples/schedule_explorer.py \
        --topo "torus2d:6x6@fail(0-1)"
    PYTHONPATH=src python examples/schedule_explorer.py --topo hypercube3 \
        --cache /tmp/schedules   # second run replays the artifact
"""
import argparse

from repro.api import Collectives
from repro.core import (simulate_allgather, simulate_allreduce,
                        rs_ag_allreduce_runtime, re_bc_allreduce_runtime)
from repro.topo import resolve_topology, zoo_specs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--topo", default="fig1a",
                    help="zoo row name or TopologySpec string "
                         f"(zoo: {', '.join(sorted(zoo_specs()))})")
    ap.add_argument("--chunks", type=int, default=32)
    ap.add_argument("--cache", default="",
                    help="schedule artifact cache dir (skip recompilation)")
    args = ap.parse_args()

    g = resolve_topology(args.topo)
    print(g.describe())
    coll = Collectives(cache=args.cache or None, num_chunks=args.chunks,
                       verify=True)
    sched = coll.schedule(g, kind="allgather")
    if coll.cache is not None:
        print(coll.cache.describe())
    print(f"\nallgather: {sched.describe()}")
    print(f"tree classes: {len(sched.classes)}  "
          f"(depths <= {sched.depth})")
    rep = simulate_allgather(sched)
    print(f"simulated: {rep.describe()}")
    print("\nbusiest physical links (bytes, per unit data):")
    top = sorted(rep.link_bytes.items(), key=lambda kv: -kv[1])[:8]
    for (u, v), b in top:
        print(f"  {u:3d} -> {v:3d}: {float(b):.4f}")
    print(f"\nallreduce RS+AG factor: {rs_ag_allreduce_runtime(g)} "
          f"vs RE+BC {re_bc_allreduce_runtime(g)}")
    ar = simulate_allreduce(coll.schedule(g, kind="allreduce"))
    print(f"allreduce achieved: {ar.describe()}")


if __name__ == "__main__":
    main()
