"""Explore the schedule compiler on any zoo topology: optimality search,
edge splitting, tree packing, chunked pipelining, physical-link loads.

    PYTHONPATH=src python examples/schedule_explorer.py --topo dragonfly
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (compile_allgather, compile_allreduce,
                        simulate_allgather, simulate_allreduce,
                        rs_ag_allreduce_runtime, re_bc_allreduce_runtime)
from repro import topo

TOPOS = {
    "fig1a": topo.fig1a,
    "ring8": lambda: topo.ring(8),
    "torus4x4": lambda: topo.torus_2d(4, 4),
    "fat_tree": topo.fat_tree,
    "dragonfly": topo.dragonfly,
    "dgx": topo.dgx_box,
    "multipod": lambda: topo.multipod_topology(2, 4, 10, 1),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--topo", default="fig1a", choices=sorted(TOPOS))
    ap.add_argument("--chunks", type=int, default=32)
    args = ap.parse_args()

    g = TOPOS[args.topo]()
    print(g.describe())
    sched = compile_allgather(g, num_chunks=args.chunks, verify=True)
    print(f"\nallgather: {sched.describe()}")
    print(f"tree classes: {len(sched.classes)}  "
          f"(depths <= {sched.depth})")
    rep = simulate_allgather(sched)
    print(f"simulated: {rep.describe()}")
    print("\nbusiest physical links (bytes, per unit data):")
    top = sorted(rep.link_bytes.items(), key=lambda kv: -kv[1])[:8]
    for (u, v), b in top:
        print(f"  {u:3d} -> {v:3d}: {float(b):.4f}")
    print(f"\nallreduce RS+AG factor: {rs_ag_allreduce_runtime(g)} "
          f"vs RE+BC {re_bc_allreduce_runtime(g)}")
    ar = simulate_allreduce(compile_allreduce(g, num_chunks=args.chunks))
    print(f"allreduce achieved: {ar.describe()}")


if __name__ == "__main__":
    main()
