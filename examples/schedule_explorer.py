"""Explore the schedule compiler on any topology: optimality search, edge
splitting, tree packing, chunked pipelining, physical-link loads.

``--topo`` takes a committed zoo row name OR any `TopologySpec` string
(full grammar, transforms included) — no code edit needed for new fabrics:

    PYTHONPATH=src python examples/schedule_explorer.py --topo dragonfly
    PYTHONPATH=src python examples/schedule_explorer.py \
        --topo "torus2d:6x6@fail(0-1)"
    PYTHONPATH=src python examples/schedule_explorer.py --topo hypercube3 \
        --cache /tmp/schedules   # second run replays the artifact
    PYTHONPATH=src python examples/schedule_explorer.py \
        --topo circulant16 --kind alltoall   # per-source pruned scatter
"""
import argparse

from repro.api import Collectives
from repro.core import (simulate_allgather, simulate_allreduce,
                        simulate_alltoall, rs_ag_allreduce_runtime,
                        re_bc_allreduce_runtime)
from repro.topo import resolve_topology, zoo_specs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--topo", default="fig1a",
                    help="zoo row name or TopologySpec string "
                         f"(zoo: {', '.join(sorted(zoo_specs()))})")
    ap.add_argument("--kind", default="allgather",
                    choices=("allgather", "alltoall"),
                    help="primary collective to explore (allreduce always "
                         "rides along for allgather)")
    ap.add_argument("--chunks", type=int, default=32)
    ap.add_argument("--cache", default="",
                    help="schedule artifact cache dir (skip recompilation)")
    args = ap.parse_args()

    g = resolve_topology(args.topo)
    print(g.describe())
    # alltoall pipelines over the N-1 destination blocks, not over chunk
    # subdivisions — P=1 is the sweep-grade configuration
    chunks = 1 if args.kind == "alltoall" else args.chunks
    coll = Collectives(cache=args.cache or None, num_chunks=chunks,
                       verify=True)
    sched = coll.schedule(g, kind=args.kind)
    if coll.cache is not None:
        print(coll.cache.describe())
    print(f"\n{args.kind}: {sched.describe()}")
    print(f"tree classes: {len(sched.classes)}  "
          f"(depths <= {sched.depth})")
    sim = (simulate_alltoall if args.kind == "alltoall"
           else simulate_allgather)
    rep = sim(sched)
    print(f"simulated: {rep.describe()}")
    print("\nbusiest physical links (bytes, per unit data):")
    top = sorted(rep.link_bytes.items(), key=lambda kv: -kv[1])[:8]
    for (u, v), b in top:
        print(f"  {u:3d} -> {v:3d}: {float(b):.4f}")
    if args.kind == "alltoall":
        return
    print(f"\nallreduce RS+AG factor: {rs_ag_allreduce_runtime(g)} "
          f"vs RE+BC {re_bc_allreduce_runtime(g)}")
    ar = simulate_allreduce(coll.schedule(g, kind="allreduce"))
    print(f"allreduce achieved: {ar.describe()}")


if __name__ == "__main__":
    main()
