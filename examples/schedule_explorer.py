"""Explore the schedule compiler on any zoo topology: optimality search,
edge splitting, tree packing, chunked pipelining, physical-link loads.

    PYTHONPATH=src python examples/schedule_explorer.py --topo dragonfly
    PYTHONPATH=src python examples/schedule_explorer.py --topo hypercube3 \
        --cache /tmp/schedules   # second run replays the artifact
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (compile_allgather, compile_allreduce,
                        simulate_allgather, simulate_allreduce,
                        rs_ag_allreduce_runtime, re_bc_allreduce_runtime)
from repro import topo
from repro.cache import ScheduleCache, sweep_registry

# every sweep topology (hypercube/BCube/mesh-of-DGX/degraded included)
# plus a couple of explorer-only aliases
TOPOS = dict(sweep_registry())
TOPOS.update({
    "fat_tree": topo.fat_tree,
    "dgx": topo.dgx_box,
})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--topo", default="fig1a", choices=sorted(TOPOS))
    ap.add_argument("--chunks", type=int, default=32)
    ap.add_argument("--cache", default="",
                    help="schedule artifact cache dir (skip recompilation)")
    args = ap.parse_args()

    g = TOPOS[args.topo]()
    print(g.describe())
    if args.cache:
        cache = ScheduleCache(args.cache, verify_on_compile=True)
        sched = cache.allgather(g, num_chunks=args.chunks)
        print(cache.describe())
    else:
        sched = compile_allgather(g, num_chunks=args.chunks, verify=True)
    print(f"\nallgather: {sched.describe()}")
    print(f"tree classes: {len(sched.classes)}  "
          f"(depths <= {sched.depth})")
    rep = simulate_allgather(sched)
    print(f"simulated: {rep.describe()}")
    print("\nbusiest physical links (bytes, per unit data):")
    top = sorted(rep.link_bytes.items(), key=lambda kv: -kv[1])[:8]
    for (u, v), b in top:
        print(f"  {u:3d} -> {v:3d}: {float(b):.4f}")
    print(f"\nallreduce RS+AG factor: {rs_ag_allreduce_runtime(g)} "
          f"vs RE+BC {re_bc_allreduce_runtime(g)}")
    ar = simulate_allreduce(compile_allreduce(g, num_chunks=args.chunks))
    print(f"allreduce achieved: {ar.describe()}")


if __name__ == "__main__":
    main()
