#!/usr/bin/env python
"""Docs-drift checker (run in CI and by tests/test_docs.py).

Four independent checks over the documentation suite:

1. **Links** — every relative markdown link in README.md, docs/*.md,
   src/repro/cache/README.md and ROADMAP.md resolves to an existing file
   (anchors stripped; http(s)/mailto links skipped).

2. **CLI flag drift** — the `--flags` documented for `benchmarks/run.py`
   and `python -m repro.cache.sweep` must match the argparse definitions
   (`build_parser()` in each).  Both directions are enforced: a documented
   flag that the parser dropped fails, and a parser flag no doc mentions
   fails.  Attribution is per paragraph: any `--flag` token in a paragraph
   that names one of the two CLIs is checked against that CLI's parser.

3. **Module paths** — every `src/repro/...*.py` and `tests/golden/*.json`
   path named in docs/ALGORITHM.md must exist, and every `(`symbol`, ...)`
   list following a module path must resolve via getattr on the imported
   module — the paper-construction table cannot rot silently (this is how
   the `repro.api` / `repro.topo.spec` entry-point map stays honest).

4. **Deprecation gate** — no in-repo caller (src/, examples/, tools/,
   benchmarks/) may reference the deprecated module-level entry points
   (`schedules_for_topology` / `programs_for_topology`); everything routes
   through `repro.api.Collectives` + `repro.topo.spec.TopologySpec`.  Only
   the shim module itself (and its package re-export, kept for external
   callers) is exempt.  Complements the tier-1 runtime gate
   (`ReproDeprecationWarning` promoted to error in pyproject.toml).

5. **BENCH perf numbers** — every `<!-- BENCH_TABLE:<kind> -->` ...
   `<!-- /BENCH_TABLE -->` block in README.md / the cache README must
   byte-match a fresh render from the committed `BENCH_schedules.json`,
   so perf numbers quoted in docs always come from the regenerated
   scoreboard (stale copies fail CI).  ``--fix`` rewrites the blocks in
   place after a BENCH regeneration.

Exit code 0 = clean; non-zero prints every violation.
"""
from __future__ import annotations

import importlib
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO / "benchmarks"))

LINK_DOCS = ["README.md", "ROADMAP.md", "src/repro/cache/README.md"]
FLAG_DOCS = ["README.md", "src/repro/cache/README.md"]
FLAG_RE = re.compile(r"(?<![\w-])--[a-z][a-z0-9-]*")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _docs(extra_glob: str = "docs/*.md"):
    files = [REPO / p for p in LINK_DOCS]
    files += sorted(REPO.glob(extra_glob))
    return [f for f in files if f.exists()]


def check_links() -> list:
    errors = []
    for f in _docs():
        for m in LINK_RE.finditer(f.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = (f.parent / target.split("#")[0]).resolve()
            if not path.exists():
                errors.append(f"{f.relative_to(REPO)}: broken link {target}")
    return errors


def _parser_flags(parser) -> set:
    flags = set()
    for action in parser._actions:  # noqa: SLF001 — argparse has no API
        flags.update(s for s in action.option_strings if s.startswith("--"))
    flags.discard("--help")
    return flags


def check_flags() -> list:
    import run as bench_run                      # benchmarks/run.py
    from repro.cache import sweep as sweep_mod

    clis = {
        "benchmarks/run.py": _parser_flags(bench_run.build_parser()),
        "repro.cache.sweep": _parser_flags(sweep_mod.build_parser()),
    }
    errors = []
    documented = {name: set() for name in clis}
    flag_files = [REPO / p for p in FLAG_DOCS] + sorted(REPO.glob("docs/*.md"))
    for f in flag_files:
        if not f.exists():
            continue
        for para in re.split(r"\n\s*\n", f.read_text()):
            flags = set(FLAG_RE.findall(para))
            if not flags:
                continue
            for name, actual in clis.items():
                if name not in para:
                    continue
                documented[name] |= flags
                stale = flags - actual
                if stale:
                    errors.append(
                        f"{f.relative_to(REPO)}: documents "
                        f"{sorted(stale)} for {name}, not in its argparse "
                        f"definition")
    for name, actual in clis.items():
        missing = actual - documented[name]
        if missing:
            errors.append(
                f"{name}: flags {sorted(missing)} are not documented in "
                f"any of {FLAG_DOCS + ['docs/*.md']}")
    return errors


def check_module_paths() -> list:
    errors = []
    algo = REPO / "docs" / "ALGORITHM.md"
    text = algo.read_text()
    for path in set(re.findall(r"(?:src|tests)/[\w./-]+\.(?:py|json)", text)):
        if not (REPO / path).exists():
            errors.append(f"docs/ALGORITHM.md: named path {path} missing")
    # `src/repro/x/y.py` (`sym`, `sym2`) — symbols must resolve
    for path, syms in re.findall(
            r"`(src/repro/[\w/]+\.py)`\s*\(([^)]*)`\)", text):
        mod_name = path[len("src/"):-len(".py")].replace("/", ".")
        try:
            mod = importlib.import_module(mod_name)
        except ImportError as e:
            errors.append(f"docs/ALGORITHM.md: cannot import {mod_name}: {e}")
            continue
        for sym in re.findall(r"`([\w.]+)`", syms + "`"):
            target = mod
            ok = True
            for part in sym.split("."):
                if not hasattr(target, part):
                    ok = False
                    break
                target = getattr(target, part)
            if not ok:
                errors.append(
                    f"docs/ALGORITHM.md: {mod_name} has no symbol {sym!r}")
    return errors


DEPRECATED_ENTRY_POINTS = ("schedules_for_topology", "programs_for_topology")
#: files that may name the deprecated entry points: the shim module that
#: defines them, the package __init__ that re-exports them for external
#: callers, and this checker
DEPRECATION_ALLOWED = {
    "src/repro/api.py",             # the facade documents what it replaces
    "src/repro/comms/executor.py",
    "src/repro/comms/__init__.py",
    "tools/check_docs.py",
}


def check_deprecated_imports() -> list:
    errors = []
    pat = re.compile(r"\b(" + "|".join(DEPRECATED_ENTRY_POINTS) + r")\b")
    for root in ("src", "examples", "tools", "benchmarks"):
        for f in sorted((REPO / root).rglob("*.py")):
            rel = str(f.relative_to(REPO))
            if rel in DEPRECATION_ALLOWED:
                continue
            for i, line in enumerate(f.read_text().splitlines(), 1):
                m = pat.search(line)
                if m:
                    errors.append(
                        f"{rel}:{i}: references deprecated entry point "
                        f"{m.group(1)!r} — route through "
                        f"repro.api.Collectives instead")
    return errors


BENCH_TABLE_RE = re.compile(
    r"<!-- BENCH_TABLE:([\w-]+) -->\n(.*?)<!-- /BENCH_TABLE -->", re.S)
BENCH_TABLE_DOCS = ["README.md", "src/repro/cache/README.md"]


def _pack_seconds(entry) -> float:
    """AG/RS §2.3 pack wall seconds of one BENCH row (v6 list or pre-v6
    mapping)."""
    cs = entry.get("compile_stats")
    if isinstance(cs, dict):
        return cs.get("pack", 0.0)
    if cs:
        return sum(r["seconds"] for r in cs if r["stage"] == "pack")
    return 0.0


def render_bench_table(kind: str, doc: dict) -> str:
    """The canonical text of one doc-embedded BENCH table.  Numbers are
    taken straight from the committed scoreboard — regenerating BENCH and
    running ``check_docs.py --fix`` is the only way docs perf numbers
    change."""
    if kind != "compile":
        raise ValueError(f"unknown BENCH_TABLE kind {kind!r}")
    from repro.cache import LARGE_NAMES
    by_name = {}
    for e in doc["entries"]:
        by_name.setdefault(e["name"], []).append(e)
    lines = [
        "| topology | compute | family compile (s) | §2.3 pack (s, AG+RS) |",
        "|---|---|---|---|",
    ]
    for name in LARGE_NAMES:
        rows = by_name.get(name)
        if not rows:
            continue
        family = sum(r["compile_time_s"] for r in rows)
        pack = sum(_pack_seconds(r) for r in rows
                   if r["kind"] in ("allgather", "reduce_scatter"))
        lines.append(f"| `{name}` | {rows[0]['num_compute']} "
                     f"| {family:.2f} | {pack:.2f} |")
    total = sum(e["compile_time_s"] for e in doc["entries"])
    lines.append(f"| **whole zoo** ({doc['num_topologies']} topologies × "
                 f"{len(doc['collectives'])} collectives) | | "
                 f"{total:.2f} | |")
    return "\n".join(lines) + "\n"


def check_bench_numbers(fix: bool = False) -> list:
    import json
    bench_path = REPO / "BENCH_schedules.json"
    doc = json.loads(bench_path.read_text())
    errors = []
    for rel in BENCH_TABLE_DOCS:
        f = REPO / rel
        text = f.read_text()
        rendered = text
        for m in BENCH_TABLE_RE.finditer(text):
            kind, body = m.group(1), m.group(2)
            try:
                expect = render_bench_table(kind, doc)
            except ValueError as e:
                errors.append(f"{rel}: {e}")
                continue
            if body != expect:
                if fix:
                    rendered = rendered.replace(m.group(0),
                                                f"<!-- BENCH_TABLE:{kind} -->"
                                                f"\n{expect}"
                                                f"<!-- /BENCH_TABLE -->")
                else:
                    errors.append(
                        f"{rel}: BENCH_TABLE:{kind} is stale vs "
                        f"BENCH_schedules.json — regenerate the sweep and "
                        f"run `python tools/check_docs.py --fix`")
        if fix and rendered != text:
            f.write_text(rendered)
            print(f"rewrote BENCH tables in {rel}")
    return errors


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fix", action="store_true",
                    help="rewrite stale doc-embedded BENCH tables from the "
                         "committed BENCH_schedules.json instead of "
                         "failing on them")
    args = ap.parse_args(argv)
    errors = (check_links() + check_flags() + check_module_paths()
              + check_deprecated_imports() + check_bench_numbers(args.fix))
    for e in errors:
        print(f"DOCS-DRIFT: {e}", file=sys.stderr)
    if not errors:
        print("docs check: links, CLI flags, module paths, BENCH perf "
              "tables, and the deprecation gate all consistent")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
