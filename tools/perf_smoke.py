#!/usr/bin/env python
"""Perf-smoke gate: fail if schedule-compile time regressed more than
--factor (default 1.25x, i.e. >25%) vs the committed
`BENCH_schedules.json` baseline — in *total* or in the §2.2 split / §2.3
pack stages individually (`compile_stats` per-stage seconds), so a
regression hiding inside one stage while another improves still fails.

The gate runs over every (topology, kind) pair shared by the measured and
baseline documents: the default fresh measurement compiles the smoke
topologies plus one scaled-up fabric (`PERF_GATE_NAMES`), and passing a
full sweep document with --measured gates every row it shares with the
baseline — including the large-topology rows.  Per-stage `compile_stats`
of the worst offenders are printed on failure so the regression points at
a stage, not just a number.  The §2.3 pack stage of the topologies in
`PACK_GATE_TOPOS` (the fast-substrate packer's poster children) is gated
on its own (measured, baseline) wall-clock pair as well.

The gate also exercises online schedule repair (`repro.core.repair`): for
every pair in `REPAIR_GATE_PAIRS` — switched fabrics under optimum-
preserving degrades, where the warm solve/split transplant pays — the
repaired artifact must (a) be byte-identical to the cold compile of the
degraded topology and (b) beat it on wall time (``repair_time_s <
cold_compile_time_s``, best-of-N to de-noise), failing the workflow
otherwise.

    python tools/perf_smoke.py                       # run + compare
    python tools/perf_smoke.py --measured /tmp/BENCH_smoke.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

#: stages gated individually (the two §2.2/§2.3 hot paths); stages whose
#: baseline share is below ABS_FLOOR seconds are not gated individually —
#: a ratio over a near-zero baseline is all timer noise.
GATED_STAGES = ("split", "pack")
ABS_FLOOR = 0.05

#: topologies whose §2.3 pack stage is additionally gated on its own
#: (measured, baseline) wall-clock pair — the pack hot-path poster child
#: must not regress even if the aggregate stage budget would absorb it.
PACK_GATE_TOPOS = ("fattree8p4l2h",)

#: (base spec, transform) pairs the repair gate times: switched topologies
#: under degrades that preserve the base optimum, so the warm transplant +
#: trace replay engages.  Harsh transforms that change (U, k) fall back to
#: cold split by design and are NOT gated on time (only on bytes, via the
#: sweep's --repair section and tests/test_repair.py).
REPAIR_GATE_PAIRS = (
    ("fig1a", "@degrade(0-9,cap=9)"),
    ("multipod:2x4", "@degrade(0-9,cap=9)"),
    ("meshdgx:2x2x4", "@degrade(0-1,cap=3)"),
)


def run_repair_gate(repeats: int = 3, num_chunks: int = 4):
    """Best-of-`repeats` cold vs repair wall time per gated pair.  Returns
    ``[(spec, transform, cold_s, repair_s, bytes_equal), ...]``.  Repair
    runs with verify=False so both sides time exactly the compile pipeline
    (the byte comparison against the verified cold artifact still pins
    correctness)."""
    from repro.cache.serialize import schedule_to_json
    from repro.core import plan as plan_mod
    from repro.core.repair import WARM, repair_schedule
    from repro.topo.spec import TopologySpec, TransformSpec

    def pipeline(g):
        p = plan_mod.plan_for("allgather", g, num_chunks=num_chunks,
                              root=None)
        return plan_mod.emit(plan_mod.rounds(plan_mod.pack(
            plan_mod.split(plan_mod.solve(p)))))

    results = []
    for base_s, tr in REPAIR_GATE_PAIRS:
        base = TopologySpec.parse(base_s).build()
        deg = TransformSpec.parse_text(tr).apply(base)
        best_cold = best_rep = float("inf")
        bytes_equal = True
        for _ in range(repeats):
            WARM.clear()
            art = pipeline(base)            # warms the oracle store
            t0 = time.perf_counter()
            cold = pipeline(deg)
            best_cold = min(best_cold, time.perf_counter() - t0)
            t0 = time.perf_counter()
            rep_art, _ = repair_schedule(art, tr, verify=False)
            best_rep = min(best_rep, time.perf_counter() - t0)
            bytes_equal &= (schedule_to_json(rep_art)
                            == schedule_to_json(cold))
        results.append((base_s, tr, best_cold, best_rep, bytes_equal))
    return results


def gate_names():
    """Topologies the default fresh measurement compiles: the smoke rows
    plus one scaled-up fabric (`repro.cache.PERF_GATE_NAMES`) so the
    large-row hot paths are exercised by the gate too."""
    from repro.cache import PERF_GATE_NAMES
    return tuple(PERF_GATE_NAMES)


def total_compile_time(doc: dict, pairs) -> float:
    """Sum compile_time_s over the given (name, kind) pairs — both sides
    of the comparison must cover the same pairs, or a partial measurement
    would be held against a fuller baseline (or vice versa)."""
    return sum(e["compile_time_s"] for e in doc["entries"]
               if (e["name"], e["kind"]) in pairs)


def stage_total(doc: dict, pairs, stage: str) -> float:
    """Sum one stage's seconds over the given pairs (rows without
    instrumentation contribute 0).  Understands both the BENCH v6
    ``[{stage, seconds, probes, augments}]`` list and the pre-v6
    ``{stage: seconds}`` mapping, so the gate still runs against an older
    committed baseline."""
    total = 0.0
    for e in doc["entries"]:
        if (e["name"], e["kind"]) not in pairs:
            continue
        cs = e.get("compile_stats")
        if isinstance(cs, dict):            # pre-v6 mapping
            total += cs.get(stage, 0.0)
        elif cs:                            # v6 list
            total += sum(row["seconds"] for row in cs
                         if row["stage"] == stage)
    return total


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=str(REPO / "BENCH_schedules.json"),
                    help="committed sweep scoreboard to compare against")
    ap.add_argument("--measured", default=None,
                    help="an already-emitted sweep JSON; omitted = sweep "
                         "the gate topologies now (jobs=1 for stable "
                         "timing)")
    ap.add_argument("--factor", type=float, default=1.25,
                    help="fail when measured > factor * baseline (total "
                         "and per gated stage)")
    ap.add_argument("--repair-repeats", type=int, default=3,
                    help="best-of-N repeats for the repair gate timings "
                         "(0 skips the repair gate)")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    from repro.cache import run_sweep

    baseline_doc = json.loads(Path(args.baseline).read_text())
    if args.measured:
        measured_doc = json.loads(Path(args.measured).read_text())
    else:
        measured_doc = run_sweep(names=gate_names(), jobs=1)

    base_pairs = {(e["name"], e["kind"]) for e in baseline_doc["entries"]}
    pairs = {(e["name"], e["kind"])
             for e in measured_doc["entries"]} & base_pairs
    if not pairs:
        print("perf-smoke: measured document shares no (name, kind) "
              "pairs with the baseline", file=sys.stderr)
        return 2

    failed = []
    checks = [("total", total_compile_time(baseline_doc, pairs),
               total_compile_time(measured_doc, pairs))]
    for stage in GATED_STAGES:
        base = stage_total(baseline_doc, pairs, stage)
        if base < ABS_FLOOR:
            continue
        checks.append((f"stage:{stage}", base,
                       stage_total(measured_doc, pairs, stage)))
    for topo in PACK_GATE_TOPOS:
        topo_pairs = {(n, k) for (n, k) in pairs if n == topo}
        if not topo_pairs:
            continue
        base = stage_total(baseline_doc, topo_pairs, "pack")
        if base < ABS_FLOOR:
            continue
        checks.append((f"pack:{topo}", base,
                       stage_total(measured_doc, topo_pairs, "pack")))
    for label, base, measured in checks:
        budget = args.factor * base
        ok = measured <= budget
        if not ok:
            failed.append(label)
        print(f"perf-smoke[{label}][{'OK' if ok else 'FAIL'}]: "
              f"measured {measured:.3f}s vs baseline {base:.3f}s "
              f"(budget {budget:.3f}s = {args.factor:.2f}x)")
    print(f"perf-smoke: {len(pairs)} (topology, kind) pairs over "
          f"{sorted({n for n, _ in pairs})}")

    if args.repair_repeats > 0:
        for spec, tr, cold_s, rep_s, same in \
                run_repair_gate(repeats=args.repair_repeats):
            ok = same and rep_s < cold_s
            if not ok:
                failed.append(f"repair:{spec}{tr}")
            print(f"perf-smoke[repair:{spec}{tr}]"
                  f"[{'OK' if ok else 'FAIL'}]: repair {rep_s:.3f}s vs "
                  f"cold {cold_s:.3f}s ({rep_s / cold_s:.2f}x) "
                  f"bytes_equal={same}")

    if not failed:
        return 0
    worst = sorted((e for e in measured_doc["entries"]
                    if (e["name"], e["kind"]) in pairs),
                   key=lambda e: -e["compile_time_s"])
    for e in worst[:5]:
        print(f"  {e['name']}.{e['kind']}: {e['compile_time_s']:.3f}s "
              f"stages={e.get('compile_stats')}", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
