#!/usr/bin/env python
"""Perf-smoke gate: fail if the smoke sweep's total compile time regressed
more than --factor (default 1.25x, i.e. >25%) vs the committed
`BENCH_schedules.json` baseline.

The baseline is the sum of `compile_time_s` over the committed entries for
the smoke topologies (all collectives); the measurement is either a
freshly-run smoke sweep (default) or an already-emitted sweep document
passed with --measured (CI reuses the smoke sweep it just ran).  Per-stage
`compile_stats` of the worst offenders are printed on failure so the
regression points at a stage, not just a number.

    python tools/perf_smoke.py                       # run + compare
    python tools/perf_smoke.py --measured /tmp/BENCH_smoke.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))


def total_compile_time(doc: dict, pairs) -> float:
    """Sum compile_time_s over the given (name, kind) pairs — both sides
    of the comparison must cover the same pairs, or a partial measurement
    would be held against a fuller baseline (or vice versa)."""
    return sum(e["compile_time_s"] for e in doc["entries"]
               if (e["name"], e["kind"]) in pairs)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=str(REPO / "BENCH_schedules.json"),
                    help="committed sweep scoreboard to compare against")
    ap.add_argument("--measured", default=None,
                    help="an already-emitted sweep JSON; omitted = run the "
                         "smoke sweep now (jobs=1 for stable timing)")
    ap.add_argument("--factor", type=float, default=1.25,
                    help="fail when measured > factor * baseline")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    from repro.cache import SMOKE_NAMES, run_sweep

    baseline_doc = json.loads(Path(args.baseline).read_text())
    if args.measured:
        measured_doc = json.loads(Path(args.measured).read_text())
    else:
        measured_doc = run_sweep(names=SMOKE_NAMES, jobs=1)

    base_pairs = {(e["name"], e["kind"]) for e in baseline_doc["entries"]}
    pairs = {(e["name"], e["kind"]) for e in measured_doc["entries"]
             if e["name"] in SMOKE_NAMES} & base_pairs
    if not pairs:
        print("perf-smoke: measured document shares no smoke (name, kind) "
              "pairs with the baseline", file=sys.stderr)
        return 2
    baseline = total_compile_time(baseline_doc, pairs)
    measured = total_compile_time(measured_doc, pairs)
    budget = args.factor * baseline
    verdict = "OK" if measured <= budget else "FAIL"
    print(f"perf-smoke[{verdict}]: measured {measured:.3f}s vs baseline "
          f"{baseline:.3f}s over {len(pairs)} (topology, kind) pairs "
          f"{sorted({n for n, _ in pairs})} "
          f"(budget {budget:.3f}s = {args.factor:.2f}x)")
    if measured <= budget:
        return 0
    worst = sorted(measured_doc["entries"], key=lambda e: -e["compile_time_s"])
    for e in worst[:5]:
        print(f"  {e['name']}.{e['kind']}: {e['compile_time_s']:.3f}s "
              f"stages={e.get('compile_stats')}", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
