#!/usr/bin/env python
"""Perf-smoke gate: fail if schedule-compile time regressed more than
--factor (default 1.25x, i.e. >25%) vs the committed
`BENCH_schedules.json` baseline — in *total* or in the §2.2 split / §2.3
pack stages individually (`compile_stats` per-stage seconds), so a
regression hiding inside one stage while another improves still fails.

The gate runs over every (topology, kind) pair shared by the measured and
baseline documents: the default fresh measurement compiles the smoke
topologies plus one scaled-up fabric (`PERF_GATE_NAMES`), and passing a
full sweep document with --measured gates every row it shares with the
baseline — including the large-topology rows.  Per-stage `compile_stats`
of the worst offenders are printed on failure so the regression points at
a stage, not just a number.

    python tools/perf_smoke.py                       # run + compare
    python tools/perf_smoke.py --measured /tmp/BENCH_smoke.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

#: stages gated individually (the two §2.2/§2.3 hot paths); stages whose
#: baseline share is below ABS_FLOOR seconds are not gated individually —
#: a ratio over a near-zero baseline is all timer noise.
GATED_STAGES = ("split", "pack")
ABS_FLOOR = 0.05


def gate_names():
    """Topologies the default fresh measurement compiles: the smoke rows
    plus one scaled-up fabric (`repro.cache.PERF_GATE_NAMES`) so the
    large-row hot paths are exercised by the gate too."""
    from repro.cache import PERF_GATE_NAMES
    return tuple(PERF_GATE_NAMES)


def total_compile_time(doc: dict, pairs) -> float:
    """Sum compile_time_s over the given (name, kind) pairs — both sides
    of the comparison must cover the same pairs, or a partial measurement
    would be held against a fuller baseline (or vice versa)."""
    return sum(e["compile_time_s"] for e in doc["entries"]
               if (e["name"], e["kind"]) in pairs)


def stage_total(doc: dict, pairs, stage: str) -> float:
    """Sum one stage's seconds over the given pairs (rows without
    instrumentation contribute 0)."""
    return sum((e.get("compile_stats") or {}).get(stage, 0.0)
               for e in doc["entries"] if (e["name"], e["kind"]) in pairs)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=str(REPO / "BENCH_schedules.json"),
                    help="committed sweep scoreboard to compare against")
    ap.add_argument("--measured", default=None,
                    help="an already-emitted sweep JSON; omitted = sweep "
                         "the gate topologies now (jobs=1 for stable "
                         "timing)")
    ap.add_argument("--factor", type=float, default=1.25,
                    help="fail when measured > factor * baseline (total "
                         "and per gated stage)")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    from repro.cache import run_sweep

    baseline_doc = json.loads(Path(args.baseline).read_text())
    if args.measured:
        measured_doc = json.loads(Path(args.measured).read_text())
    else:
        measured_doc = run_sweep(names=gate_names(), jobs=1)

    base_pairs = {(e["name"], e["kind"]) for e in baseline_doc["entries"]}
    pairs = {(e["name"], e["kind"])
             for e in measured_doc["entries"]} & base_pairs
    if not pairs:
        print("perf-smoke: measured document shares no (name, kind) "
              "pairs with the baseline", file=sys.stderr)
        return 2

    failed = []
    checks = [("total", total_compile_time(baseline_doc, pairs),
               total_compile_time(measured_doc, pairs))]
    for stage in GATED_STAGES:
        base = stage_total(baseline_doc, pairs, stage)
        if base < ABS_FLOOR:
            continue
        checks.append((f"stage:{stage}", base,
                       stage_total(measured_doc, pairs, stage)))
    for label, base, measured in checks:
        budget = args.factor * base
        ok = measured <= budget
        if not ok:
            failed.append(label)
        print(f"perf-smoke[{label}][{'OK' if ok else 'FAIL'}]: "
              f"measured {measured:.3f}s vs baseline {base:.3f}s "
              f"(budget {budget:.3f}s = {args.factor:.2f}x)")
    print(f"perf-smoke: {len(pairs)} (topology, kind) pairs over "
          f"{sorted({n for n, _ in pairs})}")
    if not failed:
        return 0
    worst = sorted((e for e in measured_doc["entries"]
                    if (e["name"], e["kind"]) in pairs),
                   key=lambda e: -e["compile_time_s"])
    for e in worst[:5]:
        print(f"  {e['name']}.{e['kind']}: {e['compile_time_s']:.3f}s "
              f"stages={e.get('compile_stats')}", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
