"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  fig1_optimality      — Fig 1/2: optimum vs ring-unwinding on the paper's
                         switch topology (derived = speedup, expect 4x)
  pipeline_convergence — §1.3: achieved/optimal ratio vs chunk count
  zoo_optimality       — eq (1) + achieved ratio across the topology zoo
  allreduce_rs_ag      — App. B: RS+AG vs RE+BC runtime factors
  broadcast_reduce_family — App. A single-root broadcast + reversed reduce
                         vs the eq (5) bound M/λ(root)
  schedule_gen_scaling — §3: strongly-polynomial generation time vs size
  schedule_sweep       — compile+verify the full topology zoo in parallel,
                         emitting BENCH_schedules.json (see repro.cache.sweep)
  jax_collectives      — wall-time of tree-pipeline vs XLA collectives on
                         8 host devices (subprocess)

Modes: default runs everything; ``--smoke`` runs only the 3-topology sweep
smoke (<60s, CI); ``--sweep`` runs only the full sweep.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import textwrap
import time
from fractions import Fraction

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import Collectives
from repro.core import (allgather_inv_xstar, re_bc_allreduce_runtime,
                        rs_ag_allreduce_runtime, simulate_allgather,
                        simulate_allreduce, simulate_broadcast,
                        simulate_reduce, solve_optimality)
from repro.topo import resolve_topology

#: one uncached facade for the whole battery — every schedule the
#: benchmarks compile goes through the repo's single front door
COLL = Collectives()


def row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


def timed(fn, *args, repeat=1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) / repeat * 1e6


# ---------------------------------------------------------------------- #

def fig1_optimality() -> None:
    """Paper Fig 1/2: edge splitting preserves the cluster cut; ring
    unwinding loses 4x."""
    g = resolve_topology("fig1a")
    opt, us = timed(solve_optimality, g)
    ring_inv = allgather_inv_xstar(resolve_topology("fig1d"))
    row("fig1_optimality.ours", us, f"inv_x*={opt.inv_x_star}")
    row("fig1_optimality.ring_unwound", us,
        f"inv_x*={ring_inv};slowdown={ring_inv / opt.inv_x_star}x")


def pipeline_convergence() -> None:
    for p in (1, 2, 4, 8, 16, 32, 64, 128):
        sched, us = timed(COLL.schedule, "fig1a", num_chunks=p)
        rep = simulate_allgather(sched)
        row(f"pipeline_convergence.P{p}", us, f"ratio={float(rep.ratio):.4f}")


def zoo_optimality() -> None:
    zoo = ("fig1a", "ring:8", "bring:8", "torus2d:4x4", "fattree",
           "dragonfly", "dgx:8", "star:8", "multipod:2x4")
    for spec in zoo:
        g = resolve_topology(spec)
        sched, us = timed(COLL.schedule, g, num_chunks=32)
        rep = simulate_allgather(sched)
        row(f"zoo_optimality.{g.name}", us,
            f"inv_x*={sched.opt.inv_x_star};k={sched.opt.k};"
            f"ratio={float(rep.ratio):.4f}")


def allreduce_rs_ag() -> None:
    for spec in ("fig1a", "ring:6", "dragonfly", "dgx:8"):
        g = resolve_topology(spec)
        (rs_ag, us) = timed(rs_ag_allreduce_runtime, g)
        re_bc = re_bc_allreduce_runtime(g)
        ar = COLL.schedule(g, kind="allreduce", num_chunks=32)
        rep = simulate_allreduce(ar)
        row(f"allreduce.{g.name}", us,
            f"rs_ag={rs_ag};re_bc={re_bc};"
            f"re_bc/rs_ag={float(re_bc / rs_ag):.2f};"
            f"achieved_ratio={float(rep.ratio):.3f}")


def broadcast_reduce_family() -> None:
    """Appendix A + dual: single-root broadcast/reduce across topologies,
    converging to the eq (5) bound M/λ(root)."""
    for spec in ("fig1a", "bring:8", "dragonfly", "star:8"):
        g = resolve_topology(spec)
        bc, us = timed(COLL.schedule, g, kind="broadcast", num_chunks=32)
        rep_bc = simulate_broadcast(bc)
        rep_red = simulate_reduce(
            COLL.schedule(g, kind="reduce", num_chunks=32))
        row(f"broadcast_reduce.{g.name}", us,
            f"lambda={bc.k};bc_ratio={float(rep_bc.ratio):.4f};"
            f"red_ratio={float(rep_red.ratio):.4f}")


def schedule_gen_scaling() -> None:
    """§3: runtime vs topology size (strongly polynomial — and capacity-
    independent: scaling all bandwidths 100x must not change the time)."""
    for n in (4, 8, 16, 24):
        _, us = timed(COLL.schedule, f"bring:{n}", num_chunks=8)
        row(f"schedule_gen.bidir_ring{n}", us, f"nodes={n}")
    for n in (4, 8, 12):
        _, us = timed(COLL.schedule, f"two_cluster:{n // 2},10,1",
                      num_chunks=8)
        row(f"schedule_gen.two_cluster{n}", us, f"nodes={n}+3sw")
    _, us1 = timed(COLL.schedule, "two_cluster:4,10,1", num_chunks=8)
    _, us100 = timed(COLL.schedule, "two_cluster:4,1000,100", num_chunks=8)
    row("schedule_gen.capacity_independence", us100,
        f"t(100x_bandwidth)/t(1x)={us100 / max(us1, 1):.2f}")


def schedule_sweep(out_path: str, smoke: bool = False,
                   cache_dir: str | None = None,
                   topologies: list[str] | None = None,
                   full: bool = False, pack_jobs: int = 1) -> None:
    """Parallel zoo sweep; every entry must reproduce its claimed runtime.
    `topologies` specs ride alongside the selected zoo rows (the smoke set
    under --smoke, the whole zoo under --sweep/the full battery), or alone
    when only --topology was given."""
    from repro.cache import (SMOKE_NAMES, claim_mismatches, run_sweep,
                             sweep_registry)
    if smoke:
        names = list(SMOKE_NAMES)
    elif full and topologies:
        names = list(sweep_registry())   # whole zoo + the extra specs
    else:
        names = None                     # run_sweep: zoo, or specs alone
    t0 = time.perf_counter()
    doc = run_sweep(names=names, cache_dir=cache_dir, out_path=out_path,
                    topologies=topologies, pack_jobs=pack_jobs)
    us = (time.perf_counter() - t0) * 1e6
    for e in doc["entries"]:
        row(f"schedule_sweep.{e['name']}", e["compile_time_s"] * 1e6,
            f"inv_x*={e['inv_x_star']};k={e['k']};depth={e['depth']};"
            f"achieved/claimed={e['achieved_over_claimed']};"
            f"achieved/lb={e['achieved_over_lb_float']:.4f}")
    bad = claim_mismatches(doc)
    row("schedule_sweep.total", us,
        f"topologies={doc['num_topologies']};claim_mismatches={len(bad)};"
        f"out={out_path}")
    if bad:
        raise SystemExit(f"schedule sweep claim mismatches: {bad}")


def jax_collectives() -> None:
    """Wall time of the executable tree-pipeline collectives vs XLA's
    built-ins on 8 host CPU devices (latency-bound toy, but end-to-end)."""
    code = textwrap.dedent("""
        import time
        import jax, jax.numpy as jnp, numpy as np
        try:
            from jax import shard_map
        except ImportError:
            from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.api import Collectives
        from repro.comms import tree_all_reduce

        mesh = Mesh(np.array(jax.devices()), ('x',))
        coll = Collectives(num_chunks=4)
        rs, ag = coll.program('bring:8', kind='allreduce')
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 1 << 16))

        tree = jax.jit(shard_map(
            lambda v: tree_all_reduce(v[0], rs, ag, 'x')[None],
            mesh=mesh, in_specs=P('x'), out_specs=P('x')))
        xla = jax.jit(shard_map(
            lambda v: jax.lax.psum(v[0], 'x')[None],
            mesh=mesh, in_specs=P('x'), out_specs=P('x')))
        for name, fn in (('tree', tree), ('xla_psum', xla)):
            fn(x).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(20):
                out = fn(x)
            out.block_until_ready()
            us = (time.perf_counter() - t0) / 20 * 1e6
            print(f'jax_collectives.allreduce_{name},{us:.1f},'
                  f'bytes={x.nbytes}')
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    if out.returncode:
        row("jax_collectives.FAILED", 0.0, out.stderr.strip()[-120:])
    else:
        print(out.stdout.strip(), flush=True)


def build_parser() -> argparse.ArgumentParser:
    """The benchmark CLI (exposed separately so tools/check_docs.py can
    assert the documented flags match)."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="3-topology schedule sweep only (<60s, CI)")
    ap.add_argument("--sweep", action="store_true",
                    help="full schedule sweep only")
    ap.add_argument("--out", default=None,
                    help="sweep output path (default: BENCH_schedules.json, "
                         "or BENCH_schedules.smoke.json under --smoke so the "
                         "committed full-sweep scoreboard is never clobbered)")
    ap.add_argument("--cache-dir", default=None,
                    help="schedule artifact cache dir for the sweep")
    ap.add_argument("--topology", nargs="*", default=None, metavar="SPEC",
                    help="sweep these extra TopologySpec strings (full "
                         "grammar incl. transforms): alongside the selected "
                         "zoo rows under --smoke/--sweep, or alone when "
                         "given by themselves — arbitrary non-zoo fabrics "
                         "without a code edit")
    ap.add_argument("--pack-jobs", type=int, default=1,
                    help="process-parallel split+pack within each family "
                         "(engages when topology-level parallelism is "
                         "inactive; schedules stay byte-identical)")
    return ap


def main(argv: list[str] | None = None) -> None:
    args = build_parser().parse_args(argv)
    if args.out is None:
        from repro.cache import default_out_path
        args.out = default_out_path(
            partial=args.smoke or args.topology is not None)

    print("name,us_per_call,derived")
    if args.smoke or args.sweep or args.topology is not None:
        schedule_sweep(args.out, smoke=args.smoke, cache_dir=args.cache_dir,
                       topologies=args.topology, full=args.sweep,
                       pack_jobs=args.pack_jobs)
        return
    fig1_optimality()
    pipeline_convergence()
    zoo_optimality()
    allreduce_rs_ag()
    broadcast_reduce_family()
    schedule_gen_scaling()
    schedule_sweep(args.out, cache_dir=args.cache_dir,
                   pack_jobs=args.pack_jobs)
    jax_collectives()


if __name__ == "__main__":
    main()
