"""Production training driver: mesh + sharding policy + sharded data +
fault-tolerant supervisor, end to end.

On a real TPU slice this runs under `jax.distributed.initialize()` with the
production 16x16 / 2x16x16 meshes; on this container it runs the same code
path over host devices (--host-devices N re-execs with a forced device
count).  The paper's collective layer plugs in at two points: the per-axis
topology models used by GSPMD cost analysis, and (collectives=pipeline) the
BucketedAllReduce gradient hook built from tree-pipeline schedules.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 50 --host-devices 8 --data-parallel 8
"""
from __future__ import annotations

import argparse
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--host-devices", type=int, default=0,
                    help="re-exec with N forced host devices (CPU testing)")
    ap.add_argument("--collectives", default="xla",
                    choices=("xla", "pipeline"),
                    help="xla: stock GSPMD all-reduces.  pipeline: gradients "
                         "flow through a BucketedAllReduce built from the "
                         "cached bandwidth-optimal allreduce artifact "
                         "(shard_map data-parallel driver; requires "
                         "--model-parallel 1)")
    ap.add_argument("--schedule-cache", default="",
                    help="pre-compile the per-axis tree-pipeline collective "
                         "programs into this on-disk artifact cache (later "
                         "launches and any pipeline-collectives consumer "
                         "load them instead of compiling)")
    ap.add_argument("--inject-fault", default="",
                    help="'step:u-v' — raise a LinkFault for link u-v at "
                         "that step.  The supervisor's on_link_fault hook "
                         "repairs the affected per-axis schedules in place "
                         "(CollectiveContext.hot_swap) and retries the same "
                         "step without restoring a checkpoint")
    args = ap.parse_args()

    if args.host_devices and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")
        os.execv(sys.executable, [sys.executable, "-m", "repro.launch.train"]
                 + [a for a in sys.argv[1:]])

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.configs import get_config, reduced_config
    from repro.models import build_model
    from repro.models.common import set_activation_sharding
    from repro.train import (AdamWConfig, FaultInjector, TrainConfig,
                             TrainSupervisor, init_adamw, make_train_step)
    from repro.train.data import DataConfig, make_global_batch
    from .sharding import batch_specs, opt_specs, param_specs, to_named

    dp, mp = args.data_parallel, args.model_parallel
    devs = jax.devices()
    if dp * mp > len(devs):
        raise SystemExit(f"need {dp * mp} devices, have {len(devs)}")
    mesh = Mesh(np.array(devs[:dp * mp]).reshape(dp, mp), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    ctx = None
    if args.schedule_cache or args.collectives == "pipeline":
        # Warm the on-disk artifact cache with this mesh's per-axis
        # tree-pipeline programs: the first launch compiles and persists,
        # later launches deserialize.  Under --collectives pipeline the
        # BucketedAllReduce gradient hook below replays the cached
        # `repro.allreduce` artifact end-to-end.
        from repro.api import Collectives
        from repro.comms import CollectiveContext
        coll = Collectives(cache=args.schedule_cache or None)
        ctx = CollectiveContext(dict(zip(mesh.axis_names,
                                         mesh.devices.shape)),
                                collectives=coll)
        print(ctx.describe())
        if coll.cache is not None:
            print(coll.cache.describe())
        if args.collectives != "pipeline":
            # pipeline mode prints the report after the allreduce artifact
            # is acquired; here the per-axis AG/RS programs are all there is
            print(ctx.compile_stats_report())

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg, remat=True)

    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    # pipeline collectives run a replicated-params shard_map DP driver, so
    # FSDP param sharding only applies to the XLA-collectives path
    p_spec = param_specs(jax.eval_shape(lambda: params), mesh,
                         fsdp=args.collectives == "xla")
    o_spec = opt_specs(p_spec)
    with mesh:
        params = jax.device_put(params, to_named(p_spec, mesh))
        opt = jax.device_put(init_adamw(params), to_named(o_spec, mesh))

    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=10,
                                           total_steps=args.steps),
                     microbatches=args.microbatches,
                     compute_dtype=jnp.float32 if args.reduced
                     else jnp.bfloat16)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.global_batch,
                    num_image_tokens=cfg.num_image_tokens,
                    encoder_seq=cfg.encoder_seq if cfg.is_encoder_decoder
                    else 0, d_model=cfg.d_model)

    batch0 = make_global_batch(dc, 0, mesh, ("data",))
    b_spec = batch_specs(jax.eval_shape(lambda: batch0), mesh)
    if args.collectives == "pipeline" and mp != 1:
        raise SystemExit("--collectives pipeline requires "
                         "--model-parallel 1")

    def build_step_jit():
        """The jitted step — rebuilt after a hot swap so the shard_map
        closure picks up the repaired ppermute programs."""
        if args.collectives == "pipeline":
            # Gradients cross devices through the paper's tree-pipeline
            # allreduce: one cached `repro.allreduce` artifact per axis,
            # lowered to ppermute programs and wrapped as the
            # BucketedAllReduce hook of make_train_step, executed inside
            # shard_map.
            try:
                from jax import shard_map
            except ImportError:
                from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            red = ctx.bucketed_allreduce("data", wire_dtype=None)
            # the cached allreduce artifact is now acquired (compiled or
            # replayed) — log which pipeline stage the time went to
            print(ctx.compile_stats_report())

            def grad_reduce(tree):
                return jax.tree.map(lambda x: x / dp, red(tree))

            base_step = make_train_step(model, tc, grad_reduce=grad_reduce)

            def spmd_step(params, opt_state, batch):
                p, o, m = base_step(params, opt_state, batch)
                # per-device diagnostics must be replicated for out_specs=P()
                m = {k: jax.lax.pmean(v, "data") for k, v in m.items()}
                return p, o, m

            kwargs = dict(mesh=mesh, in_specs=(P(), P(), P("data")),
                          out_specs=(P(), P(), P()))
            try:
                step_sm = shard_map(spmd_step, check_rep=False, **kwargs)
            except TypeError:       # newer jax: check_rep retired
                step_sm = shard_map(spmd_step, **kwargs)
            with mesh:
                return jax.jit(step_sm, donate_argnums=(0, 1))
        with mesh:
            return jax.jit(
                make_train_step(model, tc),
                in_shardings=(to_named(p_spec, mesh), to_named(o_spec, mesh),
                              to_named(b_spec, mesh)),
                out_shardings=(to_named(p_spec, mesh), to_named(o_spec, mesh),
                               None),
                donate_argnums=(0, 1))

    live = {"step_jit": build_step_jit()}
    injector = (FaultInjector.parse(args.inject_fault)
                if args.inject_fault else None)

    def step_fn(step, state):
        if injector is not None:
            injector.check(step)
        p, o = state
        batch = make_global_batch(dc, step, mesh, ("data",))
        p, o, metrics = live["step_jit"](p, o, batch)
        return (p, o), metrics

    def on_link_fault(fault):
        if ctx is None:
            # no pipeline collective state to repair — XLA collectives
            # re-route on their own; just retry the step
            print(f"[repair] {fault}: no collective context attached, "
                  f"retrying step on XLA collectives")
            return
        reports = ctx.hot_swap(fault.transform_text)
        for axis, reps in reports.items():
            for r in reps:
                print(f"[repair] axis {axis} {r.kind}: "
                      f"{r.repair_time_s * 1000:.1f}ms "
                      f"warm=(solve={r.warm_solve},split={r.warm_split}) "
                      f"cached={r.cached}")
        live["step_jit"] = build_step_jit()

    os.makedirs(args.ckpt_dir, exist_ok=True)
    sup = TrainSupervisor(ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every,
                          on_link_fault=on_link_fault)
    state, final = sup.run(state=(params, opt), num_steps=args.steps,
                           step_fn=step_fn, log_every=10)
    print(f"done at step {final}; stragglers: {len(sup.monitor.flagged)}; "
          f"link faults repaired: "
          f"{injector.fired if injector else False}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
