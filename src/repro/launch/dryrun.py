import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape) cell, on the single-pod 16x16 mesh
and the multi-pod 2x16x16 mesh:

    jax.jit(step, in_shardings=..., out_shardings=...)
        .lower(**ShapeDtypeStruct inputs)
        .compile()

must succeed; we record memory_analysis (fits per-chip HBM),
cost_analysis (FLOPs / bytes for the roofline), and the per-kind
collective bytes parsed from the HLO.  No arrays are ever allocated.

Usage:
    python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
    python -m repro.launch.dryrun --all --multi-pod both --out experiments/
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, ALL_SHAPES, get_config, shape_by_name, \
    skip_reason
from repro.models import build_model
from repro.models.common import set_activation_sharding
from repro.models.moe import set_moe_groups
from repro.models.transformer import kv_cache_len
from repro.train import AdamWConfig, TrainConfig, init_adamw, make_train_step
from repro.analysis import hlo_count
from repro.analysis.roofline import RooflineTerms, model_flops_for
from .mesh import batch_axes, make_production_mesh, mesh_axis_sizes
from .sharding import (batch_specs, decode_state_specs, opt_specs,
                       param_specs, serving_param_specs, to_named)

S = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------- #
# input specs (ShapeDtypeStructs — never allocated)
# ---------------------------------------------------------------------- #

def batch_structs(cfg, shape) -> Dict[str, Any]:
    """Model inputs for one step of the given kind."""
    b = shape.global_batch
    toks = shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.family == "vlm":
        toks = max(16, toks - cfg.num_image_tokens)
        out["patch_embed"] = S((b, cfg.num_image_tokens, cfg.d_model),
                               jnp.bfloat16)
    if cfg.family == "audio":
        out["audio_embed"] = S((b, cfg.encoder_seq, cfg.d_model),
                               jnp.bfloat16)
    out["tokens"] = S((b, toks), jnp.int32)
    return out


def seq_pad_for(cfg, n: int) -> int:
    """SSD chunked scan needs seq % chunk == 0 (all our shapes satisfy it)."""
    if cfg.ssm_state_dim and n % cfg.ssm_chunk:
        n += cfg.ssm_chunk - n % cfg.ssm_chunk
    return n


def install_activation_policy(mesh) -> None:
    """Residual stream [B,S,d]: batch over (pod,data), sequence over model
    (Megatron-style sequence parallelism — norms stay local, attention and
    MLP re-gather).  Logits [B,S,V]: vocab over model.  constrain() skips
    any tensor whose dims don't divide (decode's S=1, whisper's odd vocab)."""
    bx = batch_axes(mesh)
    set_activation_sharding({
        "residual": NamedSharding(mesh, P(bx, "model", None)),
        "logits": NamedSharding(mesh, P(bx, None, "model")),
        # blockwise attention q/k/v [B,S,H,D]: heads over model; archs with
        # fewer heads than the axis fall back to batch over every axis,
        # then batch-over-data only (attention replicated across model)
        "attn_qkv": [
            NamedSharding(mesh, P(bx, None, "model", None)),
            NamedSharding(mesh, P(bx + ("model",), None, None, None)),
            NamedSharding(mesh, P(bx, None, None, None)),
        ],
        # GQA kv before local expansion: model-replicated (cheap, few heads)
        "attn_kv_full": NamedSharding(mesh, P(bx, None, None, None)),
        # MoE grouped dispatch: groups = data shards; expert ffn dim on model
        "moe_tokens": NamedSharding(mesh, P(bx, None, None)),
        "moe_dispatch": NamedSharding(mesh, P(bx, None, None, None)),
        # ("moe_w_in"/"moe_w_out" — perf iteration B2 pinned expert
        # weights data-replicated here; measured flat, entries removed)
    })
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    set_moe_groups(int(np.prod([sizes[a] for a in bx])) if bx else 1)


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    skip: Optional[str] = None
    error: Optional[str] = None
    seconds: float = 0.0
    memory: Optional[Dict[str, float]] = None
    cost: Optional[Dict[str, float]] = None
    collective_bytes: Optional[Dict[str, int]] = None
    collective_ops: Optional[Dict[str, int]] = None
    roofline: Optional[Dict[str, Any]] = None


def _mem_dict(compiled) -> Dict[str, float]:
    m = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(m, k, None)
        if v is not None:
            out[k] = float(v)
    out["total_per_device"] = (
        out.get("argument_size_in_bytes", 0.0)
        + out.get("temp_size_in_bytes", 0.0)
        + out.get("output_size_in_bytes", 0.0)
        - out.get("alias_size_in_bytes", 0.0))
    return out


def _cost_dict(compiled) -> Dict[str, float]:
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0]
    return {k: float(v) for k, v in c.items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or "utilization" not in k)}


# ---------------------------------------------------------------------- #
# per-cell lowering
# ---------------------------------------------------------------------- #

def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               donate: bool = True) -> CellResult:
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    reason = skip_reason(arch, shape)
    if reason:
        return CellResult(arch, shape_name, mesh_name, ok=True, skip=reason)

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = int(np.prod(mesh.devices.shape))
        model = build_model(cfg, remat=True)

        if shape.kind == "train":
            lowered = _lower_train(model, cfg, shape, mesh)
        elif shape.kind == "prefill":
            lowered = _lower_prefill(model, cfg, shape, mesh)
        else:
            lowered = _lower_decode(model, cfg, shape, mesh)
        compiled = lowered.compile()
        hlo = compiled.as_text()
        # trip-adjusted counts (XLA's cost_analysis counts scan bodies once)
        counted = hlo_count.count(hlo)
        coll = counted["collective_bytes"]
        ops = counted["collective_ops"]
        cost = _cost_dict(compiled)
        cost["flops_trip_adjusted"] = counted["flops"]
        cost["bytes_trip_adjusted"] = counted["bytes"]
        mem = _mem_dict(compiled)
        terms = RooflineTerms(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
            hlo_flops=counted["flops"],
            hlo_bytes=counted["bytes"],
            collective_bytes=coll,
            model_flops=model_flops_for(cfg, shape))
        return CellResult(arch, shape_name, mesh_name, ok=True,
                          seconds=time.time() - t0, memory=mem, cost=cost,
                          collective_bytes=coll, collective_ops=ops,
                          roofline=terms.row())
    except Exception:  # noqa: BLE001 — any lowering failure is a bug report
        return CellResult(arch, shape_name, mesh_name, ok=False,
                          seconds=time.time() - t0,
                          error=traceback.format_exc(limit=6))


def _lower_train(model, cfg, shape, mesh):
    install_activation_policy(mesh)
    # B1 layout: live params bf16, f32 master + moments in the optimizer
    # (grads reduce in bf16 — half the DP gradient wire bytes)
    params_s = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), jnp.bfloat16))
    p_spec = param_specs(params_s, mesh, fsdp=True)
    tc = TrainConfig(optimizer=AdamWConfig(), microbatches=1,
                     compute_dtype=jnp.bfloat16)
    step = make_train_step(model, tc)
    opt_s = jax.eval_shape(lambda p: init_adamw(p, keep_master=True),
                           params_s)
    batch_s = batch_structs(cfg, shape)
    o_spec = opt_specs(p_spec, keep_master=True)
    b_spec = batch_specs(batch_s, mesh)
    with mesh:
        jitted = jax.jit(
            step,
            in_shardings=(to_named(p_spec, mesh), to_named(o_spec, mesh),
                          to_named(b_spec, mesh)),
            out_shardings=(to_named(p_spec, mesh), to_named(o_spec, mesh),
                           None),
            donate_argnums=(0, 1))
        return jitted.lower(params_s, opt_s, batch_s)


def _lower_prefill(model, cfg, shape, mesh):
    install_activation_policy(mesh)
    params_s = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), jnp.bfloat16))
    seq = seq_pad_for(cfg, shape.seq_len)
    state_s = jax.eval_shape(
        lambda: model.init_decode_state(shape.global_batch, seq,
                                        jnp.bfloat16))
    batch_s = batch_structs(cfg, shape)
    p_spec = serving_param_specs(params_s, mesh)
    st_spec = decode_state_specs(state_s, cfg, mesh)
    b_spec = batch_specs(batch_s, mesh)
    with mesh:
        jitted = jax.jit(
            model.prefill,
            in_shardings=(to_named(p_spec, mesh), to_named(b_spec, mesh),
                          to_named(st_spec, mesh)),
            out_shardings=(to_named(st_spec, mesh), None),
            donate_argnums=(2,))
        return jitted.lower(params_s, batch_s, state_s)


def _lower_decode(model, cfg, shape, mesh):
    install_activation_policy(mesh)
    params_s = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), jnp.bfloat16))
    seq = seq_pad_for(cfg, shape.seq_len)
    state_s = jax.eval_shape(
        lambda: model.init_decode_state(shape.global_batch, seq,
                                        jnp.bfloat16))
    token_s = S((shape.global_batch, 1), jnp.int32)
    index_s = S((), jnp.int32)
    p_spec = serving_param_specs(params_s, mesh)
    st_spec = decode_state_specs(state_s, cfg, mesh)
    tok_spec = batch_specs(token_s, mesh)
    with mesh:
        jitted = jax.jit(
            model.decode_step,
            in_shardings=(to_named(p_spec, mesh), to_named(tok_spec, mesh),
                          to_named(st_spec, mesh), None),
            out_shardings=(None, to_named(st_spec, mesh)),
            donate_argnums=(2,))
        return jitted.lower(params_s, token_s, state_s, index_s)


# ---------------------------------------------------------------------- #
# CLI
# ---------------------------------------------------------------------- #

def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id")
    ap.add_argument("--shape", default=None, help="input-shape name")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--multi-pod", choices=("on", "off", "both"),
                    default="off")
    ap.add_argument("--out", default=None, help="JSON output directory")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
    pods = {"on": [True], "off": [False], "both": [False, True]}[
        args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                r = lower_cell(arch, shape, multi_pod=mp)
                results.append(r)
                tag = f"{arch}/{shape}/{r.mesh}"
                if r.skip:
                    print(f"SKIP {tag}: {r.skip}", flush=True)
                elif r.ok:
                    rf = r.roofline
                    print(f"OK   {tag} [{r.seconds:.1f}s] "
                          f"mem/dev={r.memory['total_per_device']/2**30:.2f}GiB "
                          f"dominant={rf['dominant']} "
                          f"compute={rf['compute_s']*1e3:.2f}ms "
                          f"memory={rf['memory_s']*1e3:.2f}ms "
                          f"collective={rf['collective_s']*1e3:.2f}ms",
                          flush=True)
                else:
                    print(f"FAIL {tag} [{r.seconds:.1f}s]\n{r.error}",
                          flush=True)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    fn = f"{arch}__{shape}__{r.mesh}.json".replace("/", "_")
                    with open(os.path.join(args.out, fn), "w") as f:
                        json.dump(dataclasses.asdict(r), f, indent=1)
    bad = [r for r in results if not r.ok]
    print(f"\n{len(results) - len(bad)}/{len(results)} cells OK")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
