"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes (data, model) — matches a v5e pod's 2-D
ICI torus.  Multi-pod: 2 x 16 x 16 = 512 chips with a leading 'pod' axis
crossing DCN.  Defined as a FUNCTION so importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh) -> Tuple[str, ...]:
    """Axes the global batch shards over (pod + data when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
