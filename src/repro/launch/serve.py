"""Production serving driver: TP-sharded params + batched engine.

Parameter/checkpoint distribution goes through the paper's collective
layer: with model-parallel > 1 the host-initialized parameters are
replicated to every device by the cached single-root broadcast artifact
(`tree_broadcast` under shard_map) before the TP sharding is applied —
serving restarts replay the artifact from the schedule cache instead of
recompiling it.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --reduced --host-devices 4 --model-parallel 4
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--batch-size", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--host-devices", type=int, default=0)
    ap.add_argument("--schedule-cache", default="",
                    help="pre-compile the model-axis tree-pipeline collective "
                         "programs into this on-disk artifact cache")
    ap.add_argument("--no-broadcast-params", action="store_true",
                    help="skip the tree-broadcast parameter distribution "
                         "(saves the broadcast schedule compile on boot "
                         "when no cache is warmed)")
    ap.add_argument("--inject-fault", default="",
                    help="'u-v' — fail link u-v on the model axis after the "
                         "broadcast schedule is compiled: the driver repairs "
                         "the program in place (CollectiveContext.hot_swap) "
                         "and distributes parameters over the degraded "
                         "fabric")
    args = ap.parse_args()

    if args.host_devices and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")
        os.execv(sys.executable, [sys.executable, "-m", "repro.launch.serve"]
                 + [a for a in sys.argv[1:]])

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.configs import get_config, reduced_config
    from repro.models import build_model
    from repro.serve import Request, ServingEngine
    from .sharding import serving_param_specs, to_named

    mp = args.model_parallel
    devs = jax.devices()[:mp]
    mesh = Mesh(np.array(devs).reshape(1, mp), ("data", "model"))

    broadcast_params = mp > 1 and not args.no_broadcast_params
    ctx = None
    if args.schedule_cache or broadcast_params:
        # Serving restarts are frequent; warm the artifact cache with the
        # model-axis tree-pipeline programs so only the first boot pays for
        # schedule compilation (pipeline-collectives consumers load them;
        # the XLA-collective engine below is unaffected).  With mp > 1 the
        # context also provides the broadcast program used to distribute
        # the parameters below.
        from repro.api import Collectives
        from repro.comms import CollectiveContext
        coll = Collectives(cache=args.schedule_cache or None)
        ctx = CollectiveContext({"data": 1, "model": mp},
                                collectives=coll)
        print(ctx.describe())
        if coll.cache is not None:
            print(coll.cache.describe())
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0),
                        jnp.float32 if args.reduced else jnp.bfloat16)
    if broadcast_params:
        # Distribute the host-initialized checkpoint through the cached
        # single-root broadcast artifact: every device ends up with the
        # root's bytes (MPI_Bcast semantics) before TP sharding applies.
        from repro.comms import tree_broadcast
        try:
            from jax import shard_map
        except ImportError:
            from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        prog = ctx.broadcast_program("model", root=0)
        if args.inject_fault:
            # a link died between boot and parameter distribution: repair
            # the compiled broadcast (and any other model-axis programs)
            # and carry on over the degraded fabric — no recompile from
            # scratch, no engine restart
            from repro.train import LinkFault
            u_s, v_s = args.inject_fault.split("-", 1)
            fault = LinkFault(int(u_s), int(v_s))
            print(f"[repair] injected {fault}")
            reports = ctx.hot_swap(fault.transform_text)
            for axis, reps in reports.items():
                for r in reps:
                    print(f"[repair] axis {axis} {r.kind}: "
                          f"{r.repair_time_s * 1e3:.1f}ms "
                          f"warm=(solve={r.warm_solve},split={r.warm_split})")
            prog = ctx.broadcast_program("model", root=0)

        def _bcast_tree(tree):
            return jax.tree.map(
                lambda x: tree_broadcast(x, prog, "model"), tree)

        kwargs = dict(mesh=mesh, in_specs=P(), out_specs=P())
        try:
            bcast = shard_map(_bcast_tree, check_rep=False, **kwargs)
        except TypeError:       # newer jax: check_rep retired
            bcast = shard_map(_bcast_tree, **kwargs)
        t0 = time.perf_counter()
        with mesh:
            params = jax.jit(bcast)(params)
        params = jax.block_until_ready(params)
        print(f"params distributed via tree broadcast "
              f"(root=0, axis=model, {mp} devices) in "
              f"{(time.perf_counter() - t0) * 1e3:.0f} ms")
    if ctx is not None:
        print(ctx.compile_stats_report())
    p_spec = serving_param_specs(jax.eval_shape(lambda: params), mesh)
    with mesh:
        params = jax.device_put(params, to_named(p_spec, mesh))
        engine = ServingEngine(model, params, batch_size=args.batch_size,
                               max_len=args.max_len)
        rng = np.random.default_rng(0)
        for i in range(args.requests):
            plen = int(rng.integers(4, 24))
            engine.submit(Request(
                uid=i,
                prompt=rng.integers(1, cfg.vocab_size, plen, dtype=np.int32),
                max_new_tokens=args.new_tokens))
        for c in engine.run():
            print(f"req {c.uid}: {c.prompt_len} prompt -> "
                  f"{len(c.tokens) - c.prompt_len} new tokens "
                  f"({c.latency_s * 1e3:.0f} ms batch)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
