"""Sharding policy: PartitionSpecs for params / optimizer / batch / decode
state, per architecture and mesh.

Training uses FSDP+TP hybrid ("zero3"): every large parameter matrix is
sharded along BOTH the data axis (FSDP — XLA all-gathers per scan step and
reduce-scatters grads) and the model axis (TP).  Serving uses TP only
(params replicated across data so decode batches scale).

All assignments are divisibility-checked against the mesh; each rule lists
fallback dims so odd shapes (whisper's 51865 vocab, 8-kv-head caches on a
16-way model axis) degrade gracefully instead of failing to lower.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig

Axis = Optional[str]


def _fits(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def _assign(shape: Sequence[int], prefs: Sequence[Tuple[int, str]],
            axis_sizes: Dict[str, int]) -> P:
    """Greedy: for each (dim, axis) preference, take it if divisible and
    neither dim nor axis is already used."""
    spec: list = [None] * len(shape)
    used_axes = set()
    for dim, axis in prefs:
        if dim >= len(shape) or spec[dim] is not None or axis in used_axes:
            continue
        if axis in axis_sizes and _fits(shape[dim], axis_sizes[axis]):
            spec[dim] = axis
            used_axes.add(axis)
    return P(*spec)


# param-name patterns -> sharding preferences, as (regex, [(dim, axis)...])
# dims are indexed on the LOGICAL tensor (without the stacked layer dim; the
# layer dim is detected and offsets the indices).
_PARAM_RULES = [
    # moe experts [E, d, ff] / [E, ff, d] MUST precede the generic matmul
    # rules: TP on the per-expert ff dim, FSDP on d
    (r"moe/w_(gate|up)$", [(2, "model"), (1, "data")]),
    (r"moe/w_down$", [(1, "model"), (2, "data")]),
    (r"embed$", [(0, "model"), (1, "data")]),
    (r"lm_head$", [(1, "model"), (0, "data")]),
    (r"(wq|wk|wv|w_gate|w_up|w_in|in_proj)$", [(1, "model"), (0, "data")]),
    (r"(wo|w_down|w_out|out_proj)$", [(0, "model"), (1, "data")]),
    (r"router$", [(1, "data")]),
    (r"conv_w$", [(1, "model")]),
    (r"conv_b$", [(0, "model")]),
]


def _param_spec(path: str, shape: Sequence[int], stacked: bool,
                axis_sizes: Dict[str, int], fsdp: bool) -> P:
    off = 1 if stacked else 0
    for pat, prefs in _PARAM_RULES:
        if re.search(pat, path):
            prefs = [(d + off, a) for (d, a) in prefs
                     if fsdp or a != "data"]
            return _assign(shape, prefs, axis_sizes)
    return P()  # norms, scalars, biases: replicated


def _is_stacked(path: str) -> bool:
    return ("layers/" in path) or path.startswith("layers")


def tree_path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_specs(params_shape: Any, mesh: Mesh, *, fsdp: bool = True) -> Any:
    """PartitionSpec pytree matching a params (shape) pytree."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def assign(path, leaf):
        p = tree_path_str(path)
        return _param_spec(p, leaf.shape, _is_stacked(p), sizes, fsdp)

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def opt_specs(param_spec_tree: Any, keep_master: bool = False) -> Any:
    """AdamW state: step replicated; mu/nu (and the f32 master copy in
    mixed-precision mode) mirror the param specs."""
    from repro.train.optimizer import AdamWState
    copy = lambda: jax.tree_util.tree_map(lambda s: s, param_spec_tree)
    return AdamWState(step=P(), mu=param_spec_tree, nu=copy(),
                      master=copy() if keep_master else None)


def batch_specs(batch_shape: Any, mesh: Mesh) -> Any:
    """Shard the batch dim over (pod, data) when divisible."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = tuple(a for a in ("pod", "data") if a in sizes)
    group = int(np.prod([sizes[a] for a in axes])) if axes else 1

    def assign(leaf):
        if leaf.ndim and _fits(leaf.shape[0], group):
            return P(axes)
        return P()

    return jax.tree_util.tree_map(assign, batch_shape)


def decode_state_specs(state_shape: Any, cfg: ModelConfig,
                       mesh: Mesh) -> Any:
    """KV caches [L,B,T,H,D]: batch over (pod,data) when divisible; heads
    over model, falling back to head_dim then cache length.  SSM states
    [L,B,H,P,N]: heads over model.  Encoder outputs [B,T,d]: batch + d."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    daxes = tuple(a for a in ("pod", "data") if a in sizes)
    dgroup = int(np.prod([sizes[a] for a in daxes])) if daxes else 1

    def assign(path, leaf):
        p = tree_path_str(path)
        shape = leaf.shape
        spec: list = [None] * len(shape)
        if len(shape) >= 2 and _fits(shape[1], dgroup):
            spec[1] = daxes          # batch dim (after layer stack dim)
        msize = sizes.get("model", 1)
        if p.startswith("kv") and len(shape) == 5:
            for dim in (3, 4, 2):    # heads, head_dim, cache length
                if _fits(shape[dim], msize):
                    spec[dim] = "model"
                    break
        elif p.startswith("ssm") and len(shape) >= 4:
            for dim in (2, 3, len(shape) - 1):
                if _fits(shape[dim], msize):
                    spec[dim] = "model"
                    break
        elif p.startswith("enc_out") and len(shape) == 3:
            if _fits(shape[0], dgroup):
                spec = [daxes, None, None]
            if _fits(shape[2], msize):
                spec[2] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(assign, state_shape)


def to_named(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def serving_param_specs(params_shape: Any, mesh: Mesh) -> Any:
    """TP only (no FSDP): decode latency cannot afford per-step allgathers."""
    return param_specs(params_shape, mesh, fsdp=False)
