"""Gated MLPs (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, Params, activation_fn, dense_init


def init_mlp(key: jax.Array, d_model: int, d_ff: int,
             dtype=jnp.float32, variant: str = "gated") -> Params:
    ks = jax.random.split(key, 3)
    if variant == "plain":
        return {
            "w_in": dense_init(ks[0], (d_model, d_ff), dtype),
            "w_out": dense_init(ks[1], (d_ff, d_model), dtype),
        }
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), dtype),
    }


def mlp_forward(p: Params, x: jax.Array, activation: str = "silu"
                ) -> jax.Array:
    act = activation_fn(activation)
    if "w_in" in p:
        return act(x @ p["w_in"]) @ p["w_out"]
    return (act(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
