"""VLM backbone (PaliGemma-style): SigLIP patch embeddings (STUB per the
assignment — `input_specs()` provides precomputed [B, P, d] patch embeddings)
prepended to text embeddings, processed by a gemma-style decoder with a
prefix-LM mask (bidirectional over the image prefix, causal over text)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, Params, cross_entropy_loss
from .transformer import (decoder_stack, embed_tokens, init_kv_caches,
                          init_lm, lm_logits, next_token_loss)


def init_vlm(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    # the language backbone owns all trainable params; the vision tower is
    # stubbed (its output arrives as an input)
    return init_lm(key, cfg, dtype)


def vlm_loss(params: Params, cfg: ModelConfig,
             batch: Dict[str, jax.Array],
             remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    """batch: patch_embed [B,P,d], tokens [B,S_text]."""
    patches = batch["patch_embed"]
    tokens = batch["tokens"]
    b, p, _ = patches.shape
    s = tokens.shape[1]
    text = embed_tokens(params, cfg, tokens)
    h = jnp.concatenate([patches.astype(text.dtype), text], axis=1)
    positions = jnp.arange(p + s)
    h, _, aux = decoder_stack(params, cfg, h, positions, prefix_len=p,
                              remat=remat)
    loss = next_token_loss(params, cfg, h[:, p:], tokens,
                           batch.get("loss_mask"))
    return loss + 0.01 * aux, loss


def vlm_prefill(params: Params, cfg: ModelConfig, patches: jax.Array,
                tokens: jax.Array, caches: Any) -> Tuple[Any, jax.Array]:
    b, p, _ = patches.shape
    s = tokens.shape[1]
    text = embed_tokens(params, cfg, tokens)
    h = jnp.concatenate([patches.astype(text.dtype), text], axis=1)
    positions = jnp.arange(p + s)
    h, caches, _ = decoder_stack(
        params, cfg, h, positions, caches=caches,
        cache_index=jnp.zeros((), jnp.int32), prefix_len=p)
    return caches, lm_logits(params, cfg, h[:, -1:])


def vlm_decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                    caches: Any, index: jax.Array
                    ) -> Tuple[jax.Array, Any]:
    """index counts from 0 at the first image patch."""
    h = embed_tokens(params, cfg, token)
    h, caches, _ = decoder_stack(
        params, cfg, h, index[None], caches=caches, cache_index=index,
        prefix_len=cfg.num_image_tokens)
    return lm_logits(params, cfg, h), caches
