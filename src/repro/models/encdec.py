"""Encoder-decoder transformer (Whisper-style).

The audio conv frontend is a STUB per the assignment: `input_specs()` feeds
precomputed frame embeddings [B, T_enc, d] (what the two conv layers would
produce).  Encoder = bidirectional self-attention stack with sinusoidal
positions; decoder = causal self-attention + cross-attention to the encoder
output.  Whisper uses plain (non-gated) GELU MLPs — cfg.mlp_variant="plain".
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .attention import FULL, CAUSAL, MaskSpec, attention_forward, init_attention
from .common import (ModelConfig, Params, constrain,
                     cross_entropy_loss, dense_init, rms_norm, stacked_init)
from .mlp import init_mlp, mlp_forward
from .transformer import embed_tokens, lm_logits, next_token_loss


def sinusoid_positions(length: int, dim: int) -> np.ndarray:
    pos = np.arange(length)[:, None]
    div = np.exp(-np.log(10000.0) * np.arange(0, dim, 2) / dim)
    table = np.zeros((length, dim), np.float32)
    table[:, 0::2] = np.sin(pos * div)
    table[:, 1::2] = np.cos(pos * div)
    return table


def init_encoder_layer(key: jax.Array, cfg: ModelConfig,
                       dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "ln_attn": jnp.zeros((d,), dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "ln_mlp": jnp.zeros((d,), dtype),
        "mlp": init_mlp(ks[1], d, cfg.d_ff, dtype, cfg.mlp_variant),
    }


def init_decoder_layer_xattn(key: jax.Array, cfg: ModelConfig,
                             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "ln_self": jnp.zeros((d,), dtype),
        "self_attn": init_attention(ks[0], cfg, dtype),
        "ln_cross": jnp.zeros((d,), dtype),
        "cross_attn": init_attention(ks[1], cfg, dtype),
        "ln_mlp": jnp.zeros((d,), dtype),
        "mlp": init_mlp(ks[2], d, cfg.d_ff, dtype, cfg.mlp_variant),
    }


def init_encdec(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype, 0.02),
        "enc_layers": stacked_init(
            ks[1], cfg.encoder_layers,
            lambda k: init_encoder_layer(k, cfg, dtype)),
        "enc_norm": jnp.zeros((cfg.d_model,), dtype),
        "dec_layers": stacked_init(
            ks[2], cfg.num_layers,
            lambda k: init_decoder_layer_xattn(k, cfg, dtype)),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }


def encode(params: Params, cfg: ModelConfig,
           audio_embed: jax.Array, remat: bool = False) -> jax.Array:
    """audio_embed: [B, T_enc, d] (stub frontend output)."""
    t = audio_embed.shape[1]
    pos_table = jnp.asarray(sinusoid_positions(t, cfg.d_model),
                            audio_embed.dtype)
    h = audio_embed + pos_table[None]
    positions = jnp.arange(t)

    def layer(lp, hh):
        a_in = rms_norm(hh, lp["ln_attn"], cfg.norm_eps)
        a_out, _ = attention_forward(lp["attn"], cfg, a_in, positions, FULL)
        hh = hh + a_out
        m_in = rms_norm(hh, lp["ln_mlp"], cfg.norm_eps)
        return hh + mlp_forward(lp["mlp"], m_in, cfg.activation)

    if remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)

    def body(hh, lp):
        return constrain(layer(lp, hh), "residual"), None

    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _cross_kv(lp: Params, cfg: ModelConfig, enc_out: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    b, t, _ = enc_out.shape
    k = (enc_out @ lp["cross_attn"]["wk"]).reshape(
        b, t, cfg.num_kv_heads, cfg.hd)
    v = (enc_out @ lp["cross_attn"]["wv"]).reshape(
        b, t, cfg.num_kv_heads, cfg.hd)
    return k, v


def decode_stack(params: Params, cfg: ModelConfig, h: jax.Array,
                 positions: jax.Array, enc_out: jax.Array,
                 caches: Optional[Any] = None,
                 cache_index: Optional[jax.Array] = None,
                 cache_positions: Optional[jax.Array] = None,
                 remat: bool = False
                 ) -> Tuple[jax.Array, Any]:
    def layer(lp, hh, cc):
        s_in = rms_norm(hh, lp["ln_self"], cfg.norm_eps)
        sub_cache = (cc[0], cc[1]) if cc is not None else None
        s_out, ncache = attention_forward(
            lp["self_attn"], cfg, s_in, positions, CAUSAL,
            cache=sub_cache, cache_index=cache_index,
            cache_positions=cache_positions)
        hh = hh + s_out
        c_in = rms_norm(hh, lp["ln_cross"], cfg.norm_eps)
        kv = _cross_kv(lp, cfg, enc_out)
        c_out, _ = attention_forward(
            lp["cross_attn"], cfg, c_in, positions, FULL, kv_override=kv)
        hh = hh + c_out
        m_in = rms_norm(hh, lp["ln_mlp"], cfg.norm_eps)
        hh = hh + mlp_forward(lp["mlp"], m_in, cfg.activation)
        return hh, ncache

    if remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)

    def body(hh, xs):
        lp, cc = xs
        out, ncache = layer(lp, hh, cc)
        return constrain(out, "residual"), ncache

    h, new_caches = jax.lax.scan(body, h, (params["dec_layers"], caches))
    return h, new_caches


def encdec_loss(params: Params, cfg: ModelConfig,
                batch: Dict[str, jax.Array],
                remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    """batch: audio_embed [B,T_enc,d], tokens [B,S_dec]."""
    enc_out = encode(params, cfg, batch["audio_embed"], remat=remat)
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = embed_tokens(params, cfg, tokens)
    h, _ = decode_stack(params, cfg, h, jnp.arange(s), enc_out, remat=remat)
    loss = next_token_loss(params, cfg, h, tokens, batch.get("loss_mask"))
    return loss, loss


def encdec_prefill(params: Params, cfg: ModelConfig,
                   audio_embed: jax.Array, tokens: jax.Array,
                   caches: Tuple[jax.Array, jax.Array]
                   ) -> Tuple[Any, jax.Array, jax.Array]:
    """Returns (caches, enc_out, last logits)."""
    enc_out = encode(params, cfg, audio_embed)
    b, s = tokens.shape
    h = embed_tokens(params, cfg, tokens)
    h, caches = decode_stack(params, cfg, h, jnp.arange(s), enc_out,
                             caches=caches,
                             cache_index=jnp.zeros((), jnp.int32))
    return caches, enc_out, lm_logits(params, cfg, h[:, -1:])


def encdec_decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                       enc_out: jax.Array,
                       caches: Tuple[jax.Array, jax.Array],
                       index: jax.Array) -> Tuple[jax.Array, Any]:
    h = embed_tokens(params, cfg, token)
    h, caches = decode_stack(params, cfg, h, index[None], enc_out,
                             caches=caches, cache_index=index)
    return lm_logits(params, cfg, h), caches
