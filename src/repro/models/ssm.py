"""Mamba2 (state-space duality / SSD) — chunked parallel scan + O(1) decode.

The SSD recurrence per head (state [P, N], input x_t [P], B_t, C_t [N]):

    h_t = exp(Δ_t A) · h_{t-1} + Δ_t · x_t ⊗ B_t
    y_t = h_t @ C_t + D · x_t

Training/prefill uses the chunked block decomposition from the Mamba2 paper
(intra-chunk quadratic attention-like term + inter-chunk state recurrence,
`lax.scan` over chunks), giving O(S·Q) work and exact equality with the
naive recurrence (tested).  Decode keeps (conv_state, ssm_state) per layer —
constant memory in sequence length, which is why mamba2/zamba2 are the
archs that run the long_500k cell.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, Params, dense_init, rms_norm


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    """(d_inner, num_heads, head_dim, state_dim)."""
    din = cfg.ssm_expand * cfg.d_model
    p = cfg.ssm_head_dim
    h = cfg.ssm_num_heads or din // p
    return din, h, p, cfg.ssm_state_dim


def init_mamba2(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    din, h, p, n = ssm_dims(cfg)
    d = cfg.d_model
    conv_dim = din + 2 * n                       # x, B, C share the conv
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * din + 2 * n + h), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, conv_dim), dtype,
                             scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((h,), dtype),         # A = -exp(A_log) = -1 init
        "D": jnp.ones((h,), dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "norm_w": jnp.zeros((din,), dtype),
        "out_proj": dense_init(ks[2], (din, d), dtype),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """x: [..., Q, H] -> [..., H, Q, Q] lower-triangular pairwise sums:
    out[i, j] = sum_{j < t <= i} x[t]  (i >= j), -inf above diagonal."""
    q = x.shape[-2]
    cs = jnp.cumsum(x, axis=-2)                               # [..., Q, H]
    diff = cs[..., :, None, :] - cs[..., None, :, :]          # [..., i, j, H]
    diff = jnp.moveaxis(diff, -1, -3)                         # [..., H, i, j]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.
    x: [B,S,H,P], dt: [B,S,H] (>0), a: [H] (<0), b,c: [B,S,N].
    Returns (y [B,S,H,P], final_state [B,H,P,N] float32).

    Mixed precision (perf iteration C1, EXPERIMENTS.md §Perf): decay terms
    (exp/cumsum) and the inter-chunk state CARRY stay float32; the large
    intra-chunk einsums and the per-chunk emitted states run in the input
    dtype (bf16 in training) — the state tensors dominate HBM traffic."""
    bs, s, h, p = x.shape
    n = b.shape[-1]
    cdt = x.dtype                                             # compute dtype
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    l = s // chunk
    dt = dt.astype(jnp.float32)
    xdt = x * dt[..., None].astype(cdt)                       # [B,S,H,P]
    da = dt * a[None, None, :].astype(jnp.float32)            # [B,S,H]

    def r(t, shape):  # reshape seq into chunks
        return t.reshape((bs, l, chunk) + shape)

    x_c, da_c = r(xdt, (h, p)), r(da, (h,))
    b_c, c_c = r(b.astype(cdt), (n,)), r(c.astype(cdt), (n,))
    da_cs = jnp.cumsum(da_c, axis=2)                          # [B,L,Q,H] f32

    # 1. intra-chunk (diagonal blocks)
    ll = jnp.exp(_segsum(da_c)).astype(cdt)                   # [B,L,H,Q,Q]
    scores = jnp.einsum("blqn,blkn->blqk", c_c, b_c)          # [B,L,Q,K]
    y_diag = jnp.einsum("blqk,blhqk,blkhp->blqhp",
                        scores, ll, x_c)

    # 2. per-chunk terminal states
    decay_states = jnp.exp(da_cs[:, :, -1:, :] - da_cs).astype(cdt)
    states = jnp.einsum("blqn,blqh,blqhp->blhpn",
                        b_c, decay_states, x_c)               # [B,L,H,P,N]

    # 3. inter-chunk recurrence (f32 carry; emits in compute dtype)
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])                 # [B,L,H] f32
    h0 = init_state.astype(jnp.float32) if init_state is not None else \
        jnp.zeros((bs, h, p, n), jnp.float32)

    def step(carry, inp):
        st, dec = inp                                        # [B,H,P,N],[B,H]
        new = carry * dec[..., None, None] + st.astype(jnp.float32)
        return new, carry.astype(cdt)                        # emit entering

    final, entering = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    entering = jnp.moveaxis(entering, 0, 1)                   # [B,L,H,P,N]

    # 4. off-diagonal: prior state read out through intra-chunk decay
    state_decay = jnp.exp(da_cs).astype(cdt)                  # [B,L,Q,H]
    y_off = jnp.einsum("blqn,blhpn,blqh->blqhp",
                       c_c, entering, state_decay)

    y = (y_diag + y_off).reshape(bs, s, h, p)
    return y, final


def ssd_reference(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                  c: jax.Array,
                  init_state: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Naive sequential recurrence — the oracle for ssd_chunked."""
    bs, s, h, p = x.shape
    n = b.shape[-1]
    h0 = init_state if init_state is not None else \
        jnp.zeros((bs, h, p, n), x.dtype)

    def step(carry, t):
        xt, dtt, bt, ct = t
        decay = jnp.exp(dtt * a[None, :])[..., None, None]    # [B,H,1,1]
        upd = (xt * dtt[..., None])[..., None] * bt[:, None, None, :]
        new = carry * decay + upd                             # [B,H,P,N]
        y = jnp.einsum("bhpn,bn->bhp", new, ct)
        return new, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), final


def _causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv; x: [B,S,C], w: [W,C].  Returns (y, new_state)
    where state is the last W-1 inputs (for decode).

    Perf iteration C2: lax.conv_general_dilated instead of a gathered
    [B,S,W,C] window tensor — the gather (and its scatter transpose in the
    backward) was ~3.6 GB of traffic per layer at 4k seq."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    full = jnp.concatenate([pad, x], axis=1)                  # [B,S+W-1,C]
    if x.shape[1] == 1:
        # decode: one dot against the window
        y = jnp.einsum("bwc,wc->bc", full, w)[:, None, :] + bias
        return jax.nn.silu(y), full[:, -(width - 1):, :]
    y = jax.lax.conv_general_dilated(
        full, w[:, None, :],                 # rhs [W, 1, C] (depthwise)
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[2]) + bias
    return jax.nn.silu(y), full[:, -(width - 1):, :]


def mamba2_forward(p: Params, cfg: ModelConfig, x: jax.Array,
                   state: Optional[Tuple[jax.Array, jax.Array]] = None,
                   ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full Mamba2 mixer.  x: [B,S,d].  state = (conv_state, ssm_state) for
    incremental decode (S small, typically 1).  Returns (out, new_state)."""
    din, h, pdim, n = ssm_dims(cfg)
    conv_state, ssm_state = state if state is not None else (None, None)

    proj = x @ p["in_proj"]                                   # [B,S,...]
    z, xbc, dt_raw = jnp.split(proj, [din, 2 * din + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # [B,S,H]
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs, b, c = jnp.split(xbc, [din, din + n], axis=-1)
    xs = xs.reshape(x.shape[0], x.shape[1], h, pdim)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))

    if x.shape[1] % cfg.ssm_chunk == 0 and x.shape[1] >= cfg.ssm_chunk:
        # intra-chunk math runs in the input dtype (C1: bf16 in training)
        y, new_ssm = ssd_chunked(xs, dt, a, b, c, cfg.ssm_chunk, ssm_state)
    else:
        y, new_ssm = ssd_reference(xs.astype(jnp.float32), dt, a,
                                   b.astype(jnp.float32),
                                   c.astype(jnp.float32), ssm_state)
    y = y.astype(jnp.float32) \
        + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(x.shape[0], x.shape[1], din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], (new_conv, new_ssm)


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32
                   ) -> Tuple[jax.Array, jax.Array]:
    din, h, pdim, n = ssm_dims(cfg)
    conv = jnp.zeros((batch, cfg.ssm_conv_width - 1, din + 2 * n), dtype)
    ssm = jnp.zeros((batch, h, pdim, n), jnp.float32)
    return conv, ssm
