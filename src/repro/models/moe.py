"""Mixture-of-Experts with top-k routing, capacity-grouped dispatch, and
optional always-on shared experts (Qwen-MoE style).

Dispatch is GROUPED for SPMD locality: tokens are reshaped to [G, T/G] where
G = number of data shards (launcher sets it via `set_moe_groups`; 1 in
single-device tests).  Each group computes its own routing cumsum and
scatters into its own [E, C_g] dispatch buffer — every op keeps the leading
G dim, so GSPMD never sees a cross-shard cumsum/scatter (the naive global
formulation makes the partitioner replicate ~hundreds of GiB).  Experts are
TP-sharded on their hidden dim; compute stays proportional to *active*
parameters, so HLO FLOPs match 6·N_active·D in the roofline.

This is the "capacity-grouped data-parallel MoE + expert slicing" layout;
per-group capacity mirrors per-device capacity in Switch/GShard.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import (ModelConfig, Params, activation_fn, constrain,
                     dense_init)
from .mlp import init_mlp, mlp_forward

_MOE_GROUPS = 1


def set_moe_groups(g: int) -> None:
    """Number of token groups (= data shards).  Launcher-owned knob."""
    global _MOE_GROUPS
    _MOE_GROUPS = max(1, int(g))


def get_moe_groups() -> int:
    return _MOE_GROUPS


def init_moe(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    e, d, ff = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), dtype, scale=0.02),
        "w_gate": dense_init(ks[1], (e, d, ff), dtype),
        "w_up": dense_init(ks[2], (e, d, ff), dtype),
        "w_down": dense_init(ks[3], (e, ff, d), dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, ff * cfg.num_shared_experts, dtype)
        p["shared_gate"] = dense_init(ks[4], (d, 1), dtype, scale=0.02)
    return p


def moe_forward(p: Params, cfg: ModelConfig, x: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B, S, d], load-balance aux loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    t = b * s
    groups = _MOE_GROUPS if t % _MOE_GROUPS == 0 else 1
    tl = t // groups
    xg = constrain(x.reshape(groups, tl, d), "moe_tokens")    # [G,Tl,d]

    logits = (xg @ p["router"]).astype(jnp.float32)           # [G,Tl,E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, k)                    # [G,Tl,k]
    weights = weights / jnp.maximum(
        weights.sum(axis=-1, keepdims=True), 1e-9)

    # per-group positions via local cumsum (axis 1 — shard-local)
    cap = int(max(1, -(-tl * k * cfg.capacity_factor // e)))
    e_flat = idx.reshape(groups, tl * k)                      # [G,Tl*k]
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)       # [G,Tl*k,E]
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=1) - 1,
                              e_flat[..., None], axis=2)[..., 0]
    keep = pos < cap
    slot = jnp.where(keep, e_flat * cap + pos, e * cap)       # overflow slot

    x_rep = jnp.repeat(xg, k, axis=1)                         # [G,Tl*k,d]
    x_rep = x_rep * keep[..., None].astype(x.dtype)

    def scatter_group(slot_g, upd_g):
        buf = jnp.zeros((e * cap + 1, d), dtype=x.dtype)
        return buf.at[slot_g].add(upd_g, mode="promise_in_bounds")

    buf = jax.vmap(scatter_group)(slot, x_rep)                # [G,E*cap+1,d]
    xe = constrain(buf[:, :e * cap].reshape(groups, e, cap, d),
                   "moe_dispatch")

    act = activation_fn(cfg.activation)
    # perf iteration B2: pin expert weights data-replicated (ff TP-sharded
    # only) at the einsum so GSPMD all-gathers the bf16 weights once per
    # layer instead of psumming f32 activation-scale partials over the
    # FSDP-sharded d contraction (was the dominant collective for MoE).
    wg = constrain(p["w_gate"], "moe_w_in")
    wu = constrain(p["w_up"], "moe_w_in")
    wd = constrain(p["w_down"], "moe_w_out")
    h = act(jnp.einsum("gecd,edf->gecf", xe, wg)) \
        * jnp.einsum("gecd,edf->gecf", xe, wu)
    ye = jnp.einsum("gecf,efd->gecd", h, wd)
    ye = constrain(ye, "moe_dispatch")

    flat = jnp.concatenate(
        [ye.reshape(groups, e * cap, d),
         jnp.zeros((groups, 1, d), dtype=ye.dtype)], axis=1)
    y_rep = jax.vmap(jnp.take, in_axes=(0, 0, None))(flat, slot, 0)
    y = (y_rep.reshape(groups, tl, k, d)
         * weights[..., None].astype(x.dtype)).sum(axis=2)    # [G,Tl,d]

    if cfg.num_shared_experts:
        gate = jax.nn.sigmoid((xg @ p["shared_gate"]).astype(jnp.float32))
        y = y + mlp_forward(p["shared"], xg, cfg.activation) \
            * gate.astype(x.dtype)

    # switch-style load balancing loss (global means)
    density = onehot.reshape(groups, tl, k, e).sum(axis=2)
    density = density.astype(jnp.float32).mean(axis=(0, 1))
    router_prob = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(density * router_prob)
    return y.reshape(b, s, d), aux


def moe_forward_alltoall(p: Params, cfg: ModelConfig, x: jax.Array,
                         axis_name: str, all_to_all=None
                         ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE forward for use INSIDE `shard_map` over
    `axis_name`: experts are sharded across the axis (device w owns the
    contiguous slice of num_experts/A experts), tokens stay data-parallel.
    Routing and capacity dropping run locally, the destination-major
    [A, (E/A)·cap, d] dispatch buffer crosses the fabric through
    `all_to_all`, each device runs its local expert slices (the full
    weights are passed in; the slice happens here), and a second
    all-to-all carries the results home.

    ``all_to_all`` defaults to ``jax.lax.all_to_all``; pass a bound
    `repro.comms.tree_all_to_all` to ride a compiled bandwidth-optimal
    schedule instead — only the transport differs, so outputs match
    exactly.

    x: [B, S, d] local token shard -> (out [B, S, d], aux loss scalar).
    """
    b, s_len, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    a = jax.lax.psum(1, axis_name)
    if e % a:
        raise ValueError(f"num_experts {e} not divisible by axis size {a}")
    el = e // a
    me = jax.lax.axis_index(axis_name)
    if all_to_all is None:
        def all_to_all(v):
            return jax.lax.all_to_all(v, axis_name, 0, 0)
    t = b * s_len
    xg = x.reshape(t, d)

    logits = (xg @ p["router"]).astype(jnp.float32)            # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, k)                     # [T,k]
    weights = weights / jnp.maximum(
        weights.sum(axis=-1, keepdims=True), 1e-9)

    # local capacity per expert: every source may ship up to `cap` tokens
    # to each expert, so an expert sees at most A·cap tokens in total
    cap = int(max(1, -(-t * k * cfg.capacity_factor // e)))
    e_flat = idx.reshape(t * k)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                              e_flat[:, None], axis=1)[:, 0]
    keep = pos < cap
    slot = jnp.where(keep, e_flat * cap + pos, e * cap)        # overflow slot
    x_rep = jnp.repeat(xg, k, axis=0) * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((e * cap + 1, d), dtype=x.dtype)
    buf = buf.at[slot].add(x_rep, mode="promise_in_bounds")
    xe = buf[:e * cap].reshape(a, el * cap, d)   # dest-major expert slabs

    recv = all_to_all(xe)                        # [A, el*cap, d]
    xr = recv.reshape(a, el, cap, d).transpose(1, 0, 2, 3) \
             .reshape(el, a * cap, d)            # per local expert, all srcs

    act = activation_fn(cfg.activation)
    wg = jax.lax.dynamic_slice_in_dim(p["w_gate"], me * el, el, axis=0)
    wu = jax.lax.dynamic_slice_in_dim(p["w_up"], me * el, el, axis=0)
    wd = jax.lax.dynamic_slice_in_dim(p["w_down"], me * el, el, axis=0)
    h = act(jnp.einsum("etd,edf->etf", xr, wg)) \
        * jnp.einsum("etd,edf->etf", xr, wu)
    ye = jnp.einsum("etf,efd->etd", h, wd)

    back = ye.reshape(el, a, cap, d).transpose(1, 0, 2, 3) \
             .reshape(a, el * cap, d)
    z = all_to_all(back)                         # [A, el*cap, d]
    flat = jnp.concatenate([z.reshape(e * cap, d),
                            jnp.zeros((1, d), dtype=z.dtype)], axis=0)
    y_rep = jnp.take(flat, slot, axis=0)
    y = (y_rep.reshape(t, k, d)
         * weights[..., None].astype(x.dtype)).sum(axis=1)

    if cfg.num_shared_experts:
        gate = jax.nn.sigmoid((xg @ p["shared_gate"]).astype(jnp.float32))
        y = y + mlp_forward(p["shared"], xg, cfg.activation) \
            * gate.astype(x.dtype)

    density = onehot.reshape(t, k, e).sum(axis=1) \
                    .astype(jnp.float32).mean(axis=0)
    router_prob = probs.mean(axis=0)
    aux = e * jnp.sum(density * router_prob)
    return y.reshape(b, s_len, d), aux
