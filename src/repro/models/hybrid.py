"""Hybrid SSM + shared-attention models (Zamba2 family) and the pure-SSM LM
(Mamba2 family).

Zamba2 interleaves Mamba2 layers with a single SHARED transformer block
(attention + MLP) applied every `hybrid_attn_every` layers — the shared
block's parameters are reused at every application (that is Zamba2's
signature trick for parameter efficiency).  We scan over groups of mamba
layers and apply the shared block between groups; its KV cache has one entry
per application site.

Simplifications vs the released checkpoints (noted in DESIGN.md): no LoRA
adapters on the shared block and no concat-with-embedding input.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import CAUSAL, attention_forward, init_attention
from .common import (ModelConfig, Params, constrain,
                     cross_entropy_loss, dense_init, rms_norm, stacked_init)
from .mlp import init_mlp, mlp_forward
from .ssm import init_mamba2, init_ssm_state, mamba2_forward
from .transformer import embed_tokens, lm_logits, next_token_loss


# ---------------------------------------------------------------------- #
# pure SSM LM (mamba2)
# ---------------------------------------------------------------------- #

def init_ssm_lm(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype, 0.02),
        "layers": stacked_init(
            ks[1], cfg.num_layers,
            lambda k: {"ln": jnp.zeros((cfg.d_model,), dtype),
                       "mamba": init_mamba2(k, cfg, dtype)}),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }


def ssm_stack(params: Params, cfg: ModelConfig, h: jax.Array,
              states: Optional[Any] = None,
              remat: bool = False) -> Tuple[jax.Array, Any]:
    """states: stacked (conv [L,B,W-1,C], ssm [L,B,H,P,N]) or None."""
    def layer(lp, hh, st):
        x_in = rms_norm(hh, lp["ln"], cfg.norm_eps)
        out, new_st = mamba2_forward(lp["mamba"], cfg, x_in, st)
        return hh + out, new_st

    if remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)

    def body(hh, xs):
        lp, st = xs
        out, new_st = layer(lp, hh, st)
        return constrain(out, "residual"), new_st

    h, new_states = jax.lax.scan(body, h, (params["layers"], states))
    return h, new_states


def ssm_lm_loss(params: Params, cfg: ModelConfig,
                batch: Dict[str, jax.Array],
                remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = embed_tokens(params, cfg, tokens)
    h, _ = ssm_stack(params, cfg, h, remat=remat)
    loss = next_token_loss(params, cfg, h, tokens, batch.get("loss_mask"))
    return loss, loss


def init_ssm_lm_states(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    conv, ssm = init_ssm_state(cfg, batch, dtype)
    stack = lambda x: jnp.broadcast_to(x[None], (cfg.num_layers,) + x.shape)
    return (stack(conv), stack(ssm))


def ssm_lm_decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                       states: Any) -> Tuple[jax.Array, Any]:
    """O(1) decode: no positions, no cache index — SSM state carries time."""
    h = embed_tokens(params, cfg, token)
    h, states = ssm_stack(params, cfg, h, states)
    return lm_logits(params, cfg, h), states


# ---------------------------------------------------------------------- #
# hybrid LM (zamba2)
# ---------------------------------------------------------------------- #

def num_shared_sites(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.hybrid_attn_every


def init_hybrid_lm(key: jax.Array, cfg: ModelConfig,
                   dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    return {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype, 0.02),
        "layers": stacked_init(
            ks[1], cfg.num_layers,
            lambda k: {"ln": jnp.zeros((cfg.d_model,), dtype),
                       "mamba": init_mamba2(k, cfg, dtype)}),
        "shared": {
            "ln_attn": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attention(ks[2], cfg, dtype),
            "ln_mlp": jnp.zeros((cfg.d_model,), dtype),
            "mlp": init_mlp(ks[3], cfg.d_model, cfg.d_ff, dtype,
                            cfg.mlp_variant),
        },
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }


def _shared_block(params: Params, cfg: ModelConfig, h: jax.Array,
                  positions: jax.Array,
                  cache: Optional[Tuple[jax.Array, jax.Array]],
                  cache_index: Optional[jax.Array]
                  ) -> Tuple[jax.Array, Any]:
    sp = params["shared"]
    a_in = rms_norm(h, sp["ln_attn"], cfg.norm_eps)
    a_out, new_cache = attention_forward(
        sp["attn"], cfg, a_in, positions, CAUSAL,
        cache=cache, cache_index=cache_index)
    h = h + a_out
    m_in = rms_norm(h, sp["ln_mlp"], cfg.norm_eps)
    return h + mlp_forward(sp["mlp"], m_in, cfg.activation), new_cache


def hybrid_stack(params: Params, cfg: ModelConfig, h: jax.Array,
                 positions: jax.Array,
                 ssm_states: Optional[Any] = None,
                 kv_caches: Optional[Any] = None,
                 cache_index: Optional[jax.Array] = None,
                 remat: bool = False
                 ) -> Tuple[jax.Array, Any, Any]:
    """Groups of `hybrid_attn_every` mamba layers, shared attention block
    between groups; leftover layers (L mod every) form an attention-free
    tail.  kv_caches: (k, v) [sites, B, T, Hkv, D]."""
    every = cfg.hybrid_attn_every
    sites = num_shared_sites(cfg)
    head_n = sites * every
    split = lambda t: (
        jax.tree.map(lambda x: x[:head_n].reshape(
            (sites, every) + x.shape[1:]), t),
        jax.tree.map(lambda x: x[head_n:], t))
    layers, tail_layers = split(params["layers"])
    states = tail_states = None
    if ssm_states is not None:
        states, tail_states = split(ssm_states)

    new_states, new_kv = [], []
    for site in range(sites):
        lp = jax.tree.map(lambda x: x[site], layers)
        st = jax.tree.map(lambda x: x[site], states) \
            if states is not None else None
        h, nst = ssm_stack({"layers": lp}, cfg, h, st, remat=remat)
        new_states.append(nst)
        kv = None
        if kv_caches is not None:
            kv = (kv_caches[0][site], kv_caches[1][site])
        h, nkv = _shared_block(params, cfg, h, positions, kv, cache_index)
        new_kv.append(nkv)
    if head_n < cfg.num_layers:
        h, tail_new = ssm_stack({"layers": tail_layers}, cfg, h, tail_states,
                                remat=remat)
        if ssm_states is not None:
            new_states.append(tail_new)
    out_states = jax.tree.map(
        lambda *xs: jnp.concatenate(list(xs), axis=0), *new_states) \
        if ssm_states is not None else None
    out_kv = None
    if kv_caches is not None:
        out_kv = (jnp.stack([c[0] for c in new_kv]),
                  jnp.stack([c[1] for c in new_kv]))
    return h, out_states, out_kv


def hybrid_lm_loss(params: Params, cfg: ModelConfig,
                   batch: Dict[str, jax.Array],
                   remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = embed_tokens(params, cfg, tokens)
    h, _, _ = hybrid_stack(params, cfg, h, jnp.arange(s), remat=remat)
    loss = next_token_loss(params, cfg, h, tokens, batch.get("loss_mask"))
    return loss, loss


def init_hybrid_caches(cfg: ModelConfig, batch: int, max_len: int,
                       dtype=jnp.float32):
    sites = num_shared_sites(cfg)
    conv, ssm = init_ssm_state(cfg, batch, dtype)
    stack_l = lambda x: jnp.broadcast_to(x[None], (cfg.num_layers,) + x.shape)
    kv_shape = (sites, batch, max_len, cfg.num_kv_heads, cfg.hd)
    return ((stack_l(conv), stack_l(ssm)),
            (jnp.zeros(kv_shape, dtype), jnp.zeros(kv_shape, dtype)))


def hybrid_decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                       ssm_states: Any, kv_caches: Any, index: jax.Array
                       ) -> Tuple[jax.Array, Any, Any]:
    h = embed_tokens(params, cfg, token)
    h, ssm_states, kv_caches = hybrid_stack(
        params, cfg, h, index[None], ssm_states, kv_caches, index)
    return lm_logits(params, cfg, h), ssm_states, kv_caches
