"""Uniform Model interface over all 10 architecture families.

    model = build_model(cfg)
    params = model.init(rng, dtype)
    loss, metrics = model.loss(params, batch)          # train
    state = model.init_decode_state(params_or_none, batch, max_len, dtype)
    state, logits = model.prefill(params, batch, state)
    logits, state = model.decode_step(params, token, state, index)

Decode state is a dict pytree — contents depend on family (KV caches for
attention models, conv+ssm states for SSM, both for hybrids, plus encoder
output for enc-dec).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, Params
from . import encdec, hybrid, transformer, vlm


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    remat: bool = False          # per-layer activation rematerialisation

    # ------------------------------------------------------------------ #
    def init(self, rng: jax.Array, dtype=jnp.float32) -> Params:
        cfg = self.cfg
        if cfg.family in ("dense", "moe"):
            return transformer.init_lm(rng, cfg, dtype)
        if cfg.family == "vlm":
            return vlm.init_vlm(rng, cfg, dtype)
        if cfg.family == "audio":
            return encdec.init_encdec(rng, cfg, dtype)
        if cfg.family == "hybrid":
            return hybrid.init_hybrid_lm(rng, cfg, dtype)
        if cfg.family == "ssm":
            return hybrid.init_ssm_lm(rng, cfg, dtype)
        raise ValueError(cfg.family)

    def init_shape(self, dtype=jnp.float32) -> Params:
        """ShapeDtypeStruct params (dry-run: no allocation)."""
        return jax.eval_shape(
            lambda: self.init(jax.random.PRNGKey(0), dtype))

    # ------------------------------------------------------------------ #
    def loss(self, params: Params, batch: Dict[str, jax.Array]
             ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        if cfg.family in ("dense", "moe"):
            return transformer.lm_loss(params, cfg, batch, remat=self.remat)
        if cfg.family == "vlm":
            return vlm.vlm_loss(params, cfg, batch, remat=self.remat)
        if cfg.family == "audio":
            return encdec.encdec_loss(params, cfg, batch, remat=self.remat)
        if cfg.family == "hybrid":
            return hybrid.hybrid_lm_loss(params, cfg, batch,
                                         remat=self.remat)
        if cfg.family == "ssm":
            return hybrid.ssm_lm_loss(params, cfg, batch, remat=self.remat)
        raise ValueError(cfg.family)

    # ------------------------------------------------------------------ #
    def init_decode_state(self, batch_size: int, max_len: int,
                          dtype=jnp.float32) -> Dict[str, Any]:
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            return {"kv": transformer.init_kv_caches(
                cfg, batch_size, max_len, dtype)}
        if cfg.family == "audio":
            return {"kv": transformer.init_kv_caches(
                cfg, batch_size, max_len, dtype),
                "enc_out": jnp.zeros(
                    (batch_size, cfg.encoder_seq, cfg.d_model), dtype)}
        if cfg.family == "hybrid":
            ssm, kv = hybrid.init_hybrid_caches(
                cfg, batch_size, max_len, dtype)
            return {"ssm": ssm, "kv": kv}
        if cfg.family == "ssm":
            return {"ssm": hybrid.init_ssm_lm_states(cfg, batch_size, dtype)}
        raise ValueError(cfg.family)

    # ------------------------------------------------------------------ #
    def prefill(self, params: Params, batch: Dict[str, jax.Array],
                state: Dict[str, Any]
                ) -> Tuple[Dict[str, Any], jax.Array]:
        cfg = self.cfg
        if cfg.family in ("dense", "moe"):
            kv, logits = transformer.lm_prefill(
                params, cfg, batch["tokens"], state["kv"])
            return {"kv": kv}, logits
        if cfg.family == "vlm":
            kv, logits = vlm.vlm_prefill(
                params, cfg, batch["patch_embed"], batch["tokens"],
                state["kv"])
            return {"kv": kv}, logits
        if cfg.family == "audio":
            kv, enc_out, logits = encdec.encdec_prefill(
                params, cfg, batch["audio_embed"], batch["tokens"],
                state["kv"])
            return {"kv": kv, "enc_out": enc_out}, logits
        if cfg.family == "hybrid":
            tokens = batch["tokens"]
            h = transformer.embed_tokens(params, cfg, tokens)
            h, ssm, kv = hybrid.hybrid_stack(
                params, cfg, h, jnp.arange(tokens.shape[1]),
                state["ssm"], state["kv"], jnp.zeros((), jnp.int32))
            logits = transformer.lm_logits(params, cfg, h[:, -1:])
            return {"ssm": ssm, "kv": kv}, logits
        if cfg.family == "ssm":
            tokens = batch["tokens"]
            h = transformer.embed_tokens(params, cfg, tokens)
            h, ssm = hybrid.ssm_stack(params, cfg, h, state["ssm"])
            logits = transformer.lm_logits(params, cfg, h[:, -1:])
            return {"ssm": ssm}, logits
        raise ValueError(cfg.family)

    # ------------------------------------------------------------------ #
    def decode_step(self, params: Params, token: jax.Array,
                    state: Dict[str, Any], index: jax.Array
                    ) -> Tuple[jax.Array, Dict[str, Any]]:
        cfg = self.cfg
        if cfg.family in ("dense", "moe"):
            logits, kv = transformer.lm_decode_step(
                params, cfg, token, state["kv"], index)
            return logits, {"kv": kv}
        if cfg.family == "vlm":
            logits, kv = vlm.vlm_decode_step(
                params, cfg, token, state["kv"], index)
            return logits, {"kv": kv}
        if cfg.family == "audio":
            logits, kv = encdec.encdec_decode_step(
                params, cfg, token, state["enc_out"], state["kv"], index)
            return logits, {"kv": kv, "enc_out": state["enc_out"]}
        if cfg.family == "hybrid":
            logits, ssm, kv = hybrid.hybrid_decode_step(
                params, cfg, token, state["ssm"], state["kv"], index)
            return logits, {"ssm": ssm, "kv": kv}
        if cfg.family == "ssm":
            logits, ssm = hybrid.ssm_lm_decode_step(
                params, cfg, token, state["ssm"])
            return logits, {"ssm": ssm}
        raise ValueError(cfg.family)


def build_model(cfg: ModelConfig, remat: bool = False) -> Model:
    return Model(cfg, remat=remat)
