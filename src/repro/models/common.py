"""Shared model building blocks (pure JAX — no flax).

Parameters are nested dicts of jnp arrays; layer stacks are stored stacked
along a leading [L, ...] axis so the forward pass is a single `lax.scan`
over layers (O(1) HLO size — essential for compiling 40-layer models for a
512-device mesh on this container).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


# ---------------------------------------------------------------------- #
# config
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads
    # attention flavour
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None
    local_global_pattern: bool = False      # gemma2: alternate local/global
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    qk_norm: bool = False
    # moe
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2 / SSD)
    ssm_state_dim: int = 0
    ssm_num_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    # hybrid (zamba2): shared attention block every k ssm layers
    hybrid_attn_every: int = 0
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500
    # vlm (paligemma): prefix-lm over image tokens
    num_image_tokens: int = 0
    # misc
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    activation: str = "silu"
    mlp_variant: str = "gated"       # gated (SwiGLU/GeGLU) | plain (fc1/fc2)
    sandwich_norm: bool = False      # gemma2 pre+post block norms
    scale_embeddings: bool = False   # gemma-family sqrt(d) embedding scale
    max_seq_len: int = 131_072
    dtype: Any = jnp.float32         # compute dtype (bf16 on TPU)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_groups(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def param_count(self) -> int:
        """Approximate parameter count (reported in roofline MODEL_FLOPS)."""
        d, v, l = self.d_model, self.vocab_size, self.num_layers
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            din = self.ssm_expand * d
            per = (d * (2 * din + 2 * self.ssm_state_dim) +  # in_proj approx
                   din * d + din)
            return emb + l * per
        att = d * self.num_heads * self.hd + 2 * d * self.num_kv_heads * self.hd \
            + self.num_heads * self.hd * d
        if self.num_experts:
            ff = self.num_experts * 3 * d * self.moe_d_ff \
                + self.num_shared_experts * 3 * d * self.moe_d_ff \
                + d * self.num_experts
        else:
            ff = 3 * d * self.d_ff
        total = emb + l * (att + ff)
        if self.is_encoder_decoder:
            total += self.encoder_layers * (att + 3 * d * self.d_ff) \
                + l * att  # cross attention
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed-in experts count)."""
        if not self.num_experts:
            return self.param_count()
        d, l = self.d_model, self.num_layers
        att = d * self.num_heads * self.hd + 2 * d * self.num_kv_heads * self.hd \
            + self.num_heads * self.hd * d
        ff_active = (self.num_experts_per_tok + self.num_shared_experts) \
            * 3 * d * self.moe_d_ff + d * self.num_experts
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return emb + l * (att + ff_active)


# ---------------------------------------------------------------------- #
# activation-sharding policy (set by the launcher; models stay mesh-free)
# ---------------------------------------------------------------------- #

_ACT_SHARDING: Dict[str, Any] = {}


def set_activation_sharding(policy: Optional[Dict[str, Any]]) -> None:
    """policy: {kind: NamedSharding} for kinds 'residual' [B,S,d] and
    'logits' [B,S,V].  The launcher installs these so GSPMD keeps the batch
    dim on the data axes instead of replicating activations."""
    _ACT_SHARDING.clear()
    if policy:
        _ACT_SHARDING.update(policy)


def _divides(x: jax.Array, sh: Any) -> bool:
    spec = getattr(sh, "spec", None)
    if spec is None:
        return True
    mesh = sh.mesh
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, names in enumerate(spec):
        if names is None:
            continue
        names = names if isinstance(names, tuple) else (names,)
        size = 1
        for n in names:
            size *= sizes[n]
        if dim >= x.ndim or x.shape[dim] % size:
            return False
    return True


def constrain(x: jax.Array, kind: str) -> jax.Array:
    """Apply the first policy candidate whose named dims divide x's shape
    (policies may be a single NamedSharding or an ordered candidate list);
    no-op when nothing fits (decode's seq=1, odd vocabs, few heads)."""
    cands = _ACT_SHARDING.get(kind)
    if cands is None:
        return x
    if not isinstance(cands, (list, tuple)):
        cands = (cands,)
    for sh in cands:
        if _divides(x, sh):
            return jax.lax.with_sharding_constraint(x, sh)
    return x


# ---------------------------------------------------------------------- #
# primitives
# ---------------------------------------------------------------------- #

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def activation_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,S,1,D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- #
# init helpers
# ---------------------------------------------------------------------- #

def dense_init(key: jax.Array, shape: Tuple[int, ...],
               dtype=jnp.float32, scale: Optional[float] = None) -> jax.Array:
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def stacked_init(key: jax.Array, num: int, init_fn) -> Any:
    """Initialise `num` copies of a param tree and stack leaves on axis 0."""
    keys = jax.random.split(key, num)
    trees = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def param_count_tree(params: Params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


# ---------------------------------------------------------------------- #
# masks
# ---------------------------------------------------------------------- #

NEG_INF = -2.0 ** 30


def causal_mask(q_len: int, kv_len: int, *, q_offset: int = 0,
                window: Optional[int] = None,
                prefix_len: int = 0) -> jax.Array:
    """[q_len, kv_len] additive mask.  q position i attends kv position j iff
    j <= i + q_offset (causal), within `window` if set, or unconditionally
    when j < prefix_len (prefix-LM bidirectional region)."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    ok = kv_pos <= q_pos
    if window is not None:
        ok &= kv_pos > q_pos - window
    if prefix_len:
        ok |= kv_pos < prefix_len
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       ignore_index: int = -100) -> jax.Array:
    """Mean token NLL; logits [..., V], labels [...] int."""
    valid = labels != ignore_index
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)
