from .common import ModelConfig, Params, cross_entropy_loss  # noqa: F401
from .model_zoo import Model, build_model  # noqa: F401
from .attention import MaskSpec, attend, set_flash_impl  # noqa: F401
