"""Decoder-only transformer LM assembly (dense + MoE families).

Layer parameters are stacked [L, ...] and the forward pass is a single
`lax.scan` over layers.  Architectures with an alternating local/global
attention pattern (gemma2) scan over layer *pairs* so each half of the pair
gets its own static MaskSpec — mask structure must be static because the
sliding-window blockwise path has a different loop shape.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (CAUSAL, MaskSpec, attention_forward, init_attention)
from .common import (ModelConfig, Params, constrain,
                     cross_entropy_loss, dense_init, rms_norm, softcap,
                     stacked_init)
from .mlp import init_mlp, mlp_forward
from .moe import init_moe, moe_forward


# ---------------------------------------------------------------------- #
# layer
# ---------------------------------------------------------------------- #

def init_decoder_layer(key: jax.Array, cfg: ModelConfig,
                       dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p: Params = {
        "ln_attn": jnp.zeros((d,), dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "ln_mlp": jnp.zeros((d,), dtype),
    }
    if cfg.num_experts:
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, dtype, cfg.mlp_variant)
    if cfg.sandwich_norm:
        p["ln_attn_post"] = jnp.zeros((d,), dtype)
        p["ln_mlp_post"] = jnp.zeros((d,), dtype)
    return p


def decoder_layer(p: Params, cfg: ModelConfig, h: jax.Array,
                  positions: jax.Array, spec: MaskSpec,
                  cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                  cache_index: Optional[jax.Array] = None,
                  cache_positions: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (h, new_cache, moe_aux)."""
    attn_in = rms_norm(h, p["ln_attn"], cfg.norm_eps)
    attn_out, new_cache = attention_forward(
        p["attn"], cfg, attn_in, positions, spec,
        cache=cache, cache_index=cache_index,
        cache_positions=cache_positions,
        logit_cap=cfg.attn_logit_softcap)
    if cfg.sandwich_norm:
        attn_out = rms_norm(attn_out, p["ln_attn_post"], cfg.norm_eps)
    h = h + attn_out
    mlp_in = rms_norm(h, p["ln_mlp"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.num_experts:
        mlp_out, aux = moe_forward(p["moe"], cfg, mlp_in)
    else:
        mlp_out = mlp_forward(p["mlp"], mlp_in, cfg.activation)
    if cfg.sandwich_norm:
        mlp_out = rms_norm(mlp_out, p["ln_mlp_post"], cfg.norm_eps)
    return h + mlp_out, new_cache, aux


# ---------------------------------------------------------------------- #
# full model
# ---------------------------------------------------------------------- #

def layer_specs(cfg: ModelConfig) -> Tuple[MaskSpec, ...]:
    """Static per-position-in-pattern mask specs.  Period 2 for gemma2's
    local/global alternation, else period 1."""
    if cfg.local_global_pattern:
        assert cfg.sliding_window, "local/global pattern needs a window"
        return (MaskSpec(causal=True, window=cfg.sliding_window),
                MaskSpec(causal=True))
    return (MaskSpec(causal=True, window=cfg.sliding_window),)


def init_lm(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype,
                            scale=0.02),
        "layers": stacked_init(
            ks[1], cfg.num_layers,
            lambda k: init_decoder_layer(k, cfg, dtype)),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.vocab_size), dtype)
    return p


def _reshape_period(tree: Params, period: int) -> Params:
    """[L, ...] stacked params -> [L/period, period, ...]."""
    return jax.tree.map(
        lambda x: x.reshape((x.shape[0] // period, period) + x.shape[1:]),
        tree)


def decoder_stack(params: Params, cfg: ModelConfig, h: jax.Array,
                  positions: jax.Array,
                  caches: Optional[Any] = None,
                  cache_index: Optional[jax.Array] = None,
                  cache_positions: Optional[jax.Array] = None,
                  prefix_len: int = 0,
                  remat: bool = False,
                  ) -> Tuple[jax.Array, Any, jax.Array]:
    """Scan the layer stack.  caches: stacked (k, v) [L, B, T, Hkv, D]."""
    specs = layer_specs(cfg)
    if prefix_len:
        specs = tuple(
            MaskSpec(causal=s.causal, window=s.window, prefix_len=prefix_len)
            for s in specs)
    period = len(specs)
    layers = _reshape_period(params["layers"], period)
    stacked_caches = None
    if caches is not None:
        stacked_caches = jax.tree.map(
            lambda x: x.reshape((x.shape[0] // period, period) + x.shape[1:]),
            caches)

    layer_fn = decoder_layer
    if remat:
        layer_fn = jax.checkpoint(
            decoder_layer,
            static_argnums=(1, 4),
            policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, xs):
        hh, aux_sum = carry
        lp, cc = xs
        new_cc = []
        for i, spec in enumerate(specs):
            sub = jax.tree.map(lambda x: x[i], lp)
            sub_cache = None
            if cc is not None:
                sub_cache = (cc[0][i], cc[1][i])
            hh, ncache, aux = layer_fn(
                sub, cfg, hh, positions, spec,
                sub_cache, cache_index, cache_positions)
            hh = constrain(hh, "residual")
            new_cc.append(ncache)
        if cc is not None:
            out_cc = (jnp.stack([c[0] for c in new_cc]),
                      jnp.stack([c[1] for c in new_cc]))
        else:
            out_cc = None
        return (hh, aux_sum + aux), out_cc

    (h, aux), new_caches = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)),
        (layers, stacked_caches))
    if new_caches is not None:
        new_caches = jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[2:]), new_caches)
    return h, new_caches, aux


def embed_tokens(params: Params, cfg: ModelConfig,
                 tokens: jax.Array) -> jax.Array:
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embeddings:
        h = h * jnp.sqrt(cfg.d_model).astype(h.dtype)
    return constrain(h, "residual")


def lm_logits(params: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = constrain(h @ params["embed"].T, "logits")
    else:
        logits = h @ params["lm_head"]
    logits = constrain(logits, "logits")
    return softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)


LOSS_CHUNK = 512   # sequence positions per logits chunk


def next_token_loss(params: Params, cfg: ModelConfig, h: jax.Array,
                    tokens: jax.Array,
                    loss_mask: Optional[jax.Array] = None) -> jax.Array:
    """Next-token cross-entropy WITHOUT materialising [B,S,V] logits: the
    vocab projection + softcap + CE run chunked over the sequence, each
    chunk rematerialised in the backward pass.  At 256k vocab the full fp32
    logits are ~4 GiB/device; chunking caps live logits at LOSS_CHUNK/S of
    that."""
    b, s = tokens.shape
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full((b, 1), -100, tokens.dtype)], axis=1)
    if loss_mask is not None:
        labels = jnp.where(loss_mask > 0, labels, -100)
    c = min(LOSS_CHUNK, s)
    pad = (-s) % c
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
    n = (s + pad) // c
    h_c = jnp.moveaxis(h.reshape(b, n, c, h.shape[-1]), 1, 0)   # [n,B,c,d]
    l_c = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)           # [n,B,c]

    @jax.checkpoint
    def chunk_nll(hc, lc):
        logits = lm_logits(params, cfg, hc)                     # [B,c,V] f32
        valid = lc != -100
        safe = jnp.where(valid, lc, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        return ((logz - gold) * valid).sum(), valid.sum()

    def body(carry, xs):
        nll, cnt = carry
        hc, lc = xs
        dn, dc = chunk_nll(hc, lc)
        return (nll + dn, cnt + dc), None

    (nll, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (h_c, l_c))
    return nll / jnp.maximum(cnt, 1)


def lm_loss(params: Params, cfg: ModelConfig,
            batch: Dict[str, jax.Array],
            prefix_len: int = 0,
            remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    """batch: tokens [B,S] int32 (+ optional loss_mask [B,S]).
    Next-token loss; MoE aux added with coefficient 0.01."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = embed_tokens(params, cfg, tokens)
    positions = jnp.arange(s)
    h, _, aux = decoder_stack(params, cfg, h, positions,
                              prefix_len=prefix_len, remat=remat)
    loss = next_token_loss(params, cfg, h, tokens,
                           batch.get("loss_mask"))
    return loss + 0.01 * aux, loss


# ---------------------------------------------------------------------- #
# serving
# ---------------------------------------------------------------------- #

def kv_cache_len(cfg: ModelConfig, max_len: int) -> int:
    """Sliding-window archs (uniform window, e.g. mixtral) only ever need
    `window` rows — the ring buffer bounds decode memory at long context."""
    if cfg.sliding_window and not cfg.local_global_pattern:
        return min(max_len, cfg.sliding_window)
    return max_len


def init_kv_caches(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    clen = kv_cache_len(cfg, max_len)
    shape = (cfg.num_layers, batch, clen, cfg.num_kv_heads, cfg.hd)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def lm_prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
               caches: Tuple[jax.Array, jax.Array],
               prefix_len: int = 0) -> Tuple[Any, jax.Array]:
    """Run the prompt through the stack, filling the caches from index 0.
    Returns (caches, last-position logits)."""
    b, s = tokens.shape
    h = embed_tokens(params, cfg, tokens)
    positions = jnp.arange(s)
    h, caches, _ = decoder_stack(
        params, cfg, h, positions, caches=caches,
        cache_index=jnp.zeros((), jnp.int32), prefix_len=prefix_len)
    return caches, lm_logits(params, cfg, h[:, -1:])


def lm_decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                   caches: Tuple[jax.Array, jax.Array],
                   index: jax.Array) -> Tuple[jax.Array, Any]:
    """One-token decode.  token: [B,1]; index: scalar int32 absolute
    position.  Ring-buffer caches (len < max positions, e.g. sliding-window
    archs) wrap the write index; row positions mask wrapped/garbage rows.
    Returns (logits [B,1,V], caches)."""
    from .attention import ring_positions
    h = embed_tokens(params, cfg, token)
    positions = index[None] if index.ndim == 0 else index
    clen = caches[0].shape[2]
    widx = jnp.mod(index, clen)
    cache_pos = ring_positions(index, clen)
    h, caches, _ = decoder_stack(
        params, cfg, h, positions, caches=caches, cache_index=widx,
        cache_positions=cache_pos)
    return lm_logits(params, cfg, h), caches
