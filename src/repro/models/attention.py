"""Attention: GQA/MQA/MHA with RoPE, sliding window, logit softcap, qk-norm,
prefix-LM, cross-attention, and KV-cache decode.

Masks are never materialised as [S, T] arrays — they are *described* by
(causal, window, prefix_len) plus position vectors and evaluated inline.
Three execution paths:

* direct      — small sequences / decode: one einsum, inline mask.
* blockwise   — long sequences: lax.map over query blocks, online-softmax
                lax.scan over KV blocks (flash attention expressed in XLA;
                O(block^2) memory).  For sliding-window attention only the
                window-adjacent KV blocks are visited, so compute is
                O(S * window) — this is what makes the long_500k cells of
                mixtral/zamba2 tractable.
* kernel      — the Pallas flash kernel (repro.kernels) on TPU; registered
                via `set_flash_impl`, validated against the paths above.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import (ModelConfig, NEG_INF, Params, apply_rope, dense_init,
                     rms_norm, softcap)

_FLASH_IMPL = None
BLOCKWISE_THRESHOLD = 2048      # use blockwise path above this many kv rows
BLOCK_Q = 1024
BLOCK_KV = 1024


def set_flash_impl(fn) -> None:
    """Register the Pallas kernel as the long-sequence implementation."""
    global _FLASH_IMPL
    _FLASH_IMPL = fn


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Logical attention mask: evaluated lazily from positions."""
    causal: bool = True
    window: Optional[int] = None        # sliding window (None = unbounded)
    prefix_len: int = 0                 # bidirectional prefix (prefix-LM)

    def allowed(self, q_pos: jax.Array, kv_pos: jax.Array) -> jax.Array:
        """q_pos: [...,S], kv_pos: [...,T] -> bool [...,S,T].
        Negative kv positions are never attended (ring-buffer caches encode
        not-yet-written rows as negative positions)."""
        qp = q_pos[..., :, None]
        kp = kv_pos[..., None, :]
        if self.causal:
            ok = kp <= qp
            if self.window is not None:
                ok &= kp > qp - self.window
        else:
            ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
        if self.prefix_len:
            ok |= kp < self.prefix_len
        return ok & (kp >= 0)


FULL = MaskSpec(causal=False)
CAUSAL = MaskSpec(causal=True)


def ring_positions(index: jax.Array, cache_len: int) -> jax.Array:
    """Absolute position held by each row of a (possibly ring-buffer) cache
    when the current decode position is `index`.  Rows never written resolve
    to negative positions, which MaskSpec.allowed() always rejects."""
    r = jnp.arange(cache_len)
    return index - jnp.mod(index - r, cache_len)


def init_attention(key: jax.Array, cfg: ModelConfig,
                   dtype=jnp.float32) -> Params:
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.num_heads * hd), dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.num_kv_heads * hd), dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.num_kv_heads * hd), dtype),
        "wo": dense_init(ks[3], (cfg.num_heads * hd, cfg.d_model), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


# ---------------------------------------------------------------------- #
# core attend
# ---------------------------------------------------------------------- #

def _direct_attend(q, k, v, q_pos, kv_pos, spec: MaskSpec,
                   logit_cap: Optional[float]) -> jax.Array:
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, d)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg, k).astype(jnp.float32)
    logits = softcap(logits / jnp.sqrt(d), logit_cap)
    ok = spec.allowed(q_pos, kv_pos)                  # [B,S,T] or [S,T]
    if ok.ndim == 2:
        ok = ok[None]
    logits = jnp.where(ok[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v)
    return out.reshape(b, s, h, d)


def _blockwise_attend(q, k, v, q_pos, kv_pos, spec: MaskSpec,
                      logit_cap: Optional[float],
                      block_q: int = BLOCK_Q,
                      block_kv: int = BLOCK_KV) -> jax.Array:
    """Online-softmax flash attention in XLA.  Sliding-window masks visit
    only the KV blocks that can intersect the window.

    Head-parallel under SPMD: GQA kv heads are expanded to full heads up
    front and q/k/v are constrained head-sharded (launcher policy
    'attn_qkv', with batch-sharded / replicated fallbacks), so the block
    loops are collective-free."""
    from .common import constrain
    b, s, h, d = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    if g > 1:
        # SP->TP boundary: gather kv to model-replicated FIRST (cheap — kv
        # heads are few), expand GQA locally, then slice into head shards.
        # Direct seq-sharded -> head-sharded resharding of the expanded kv
        # makes GSPMD fall back to full rematerialisation.
        k = constrain(k, "attn_kv_full")
        v = constrain(v, "attn_kv_full")
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        hkv = h
        g = 1
    q = constrain(q, "attn_qkv")
    k = constrain(k, "attn_qkv")
    v = constrain(v, "attn_qkv")
    bq = min(block_q, s)
    bkv = min(block_kv, t)
    pad_q = (-s) % bq
    pad_kv = (-t) % bkv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, pad_q),), constant_values=-1)
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, pad_kv),), constant_values=2 ** 30)
    sq, st = s + pad_q, t + pad_kv
    nq, nk = sq // bq, st // bkv

    windowed = spec.causal and spec.window is not None and spec.prefix_len == 0
    kblocks_per_q = nk if not windowed else \
        min(nk, -(-(spec.window + bq) // bkv) + 1)

    k_r = k.reshape(b, nk, bkv, hkv, d)
    v_r = v.reshape(b, nk, bkv, hkv, d)
    kp_r = kv_pos.reshape(nk, bkv)

    def q_block(i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * bq, bq, axis=1)
        qpi = jax.lax.dynamic_slice_in_dim(q_pos, i * bq, bq, axis=0)
        qg = qi.reshape(b, bq, hkv, g, d)

        def kv_iter(carry, j):
            m, l, acc = carry
            if windowed:
                # only blocks [j0, j0+kblocks) can intersect the window;
                # anchor on the LAST query row's diagonal block so any
                # (block_q, block_kv) alignment is covered
                jmax = ((i + 1) * bq - 1) // bkv
                j0 = jnp.maximum(0, jmax - (kblocks_per_q - 1))
                jj = jnp.minimum(j0 + j, nk - 1)
            else:
                jj = j
            kj = k_r[:, jj]                      # [B,bkv,hkv,d]
            vj = v_r[:, jj]
            kpj = kp_r[jj]
            logits = jnp.einsum("bshgd,bthd->bhgst", qg, kj
                                ).astype(jnp.float32)
            logits = softcap(logits / jnp.sqrt(d), logit_cap)
            ok = spec.allowed(qpi, kpj)
            logits = jnp.where(ok[None, None, None], logits, NEG_INF)
            blk_max = logits.max(axis=-1)                     # [B,hkv,g,bq]
            new_m = jnp.maximum(m, blk_max)
            corr = jnp.exp(m - new_m)
            p = jnp.exp(logits - new_m[..., None])
            new_l = l * corr + p.sum(axis=-1)
            # (perf iteration A1 tried p.astype(bf16) for this dot — flash
            # kernels do it on-chip — but XLA materialises the convert as a
            # separate kernel, a net traffic REGRESSION here; reverted.)
            pv = jnp.einsum("bhgst,bthd->bhgsd", p, vj.astype(jnp.float32))
            new_acc = acc * corr[..., None] + pv
            return (new_m, new_l, new_acc), None

        m0 = jnp.full((b, hkv, g, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, bq, d), jnp.float32)
        # flash backward: recompute block probabilities instead of stashing
        # them (otherwise autodiff saves O(S^2) logits across the scan)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_iter,
                           policy=jax.checkpoint_policies.nothing_saveable),
            (m0, l0, a0), jnp.arange(kblocks_per_q))
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        return jnp.moveaxis(out, 3, 1).reshape(b, bq, h, d)   # [B,bq,H,D]

    blocks = jax.lax.map(q_block, jnp.arange(nq))             # [nq,B,bq,H,D]
    out = jnp.moveaxis(blocks, 0, 1).reshape(b, sq, h, d)
    return out[:, :s].astype(q.dtype)


def attend(q: jax.Array, k: jax.Array, v: jax.Array,
           q_pos: jax.Array, kv_pos: jax.Array, spec: MaskSpec,
           logit_cap: Optional[float] = None) -> jax.Array:
    """q: [B,S,H,D], k/v: [B,T,Hkv,D], positions: [S]/[T] int."""
    t = k.shape[1]
    s = q.shape[1]
    if _FLASH_IMPL is not None and s > 1:
        return _FLASH_IMPL(q, k, v, q_pos, kv_pos, spec, logit_cap)
    if s == 1 or max(s, t) <= BLOCKWISE_THRESHOLD:
        return _direct_attend(q, k, v, q_pos, kv_pos, spec, logit_cap)
    return _blockwise_attend(q, k, v, q_pos, kv_pos, spec, logit_cap)


# ---------------------------------------------------------------------- #
# attention block with optional KV cache / cross-attention
# ---------------------------------------------------------------------- #

def attention_forward(
        p: Params, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
        spec: MaskSpec, *,
        kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
        cache: Optional[Tuple[jax.Array, jax.Array]] = None,
        cache_index: Optional[jax.Array] = None,
        cache_positions: Optional[jax.Array] = None,
        logit_cap: Optional[float] = None,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """x: [B,S,d]; positions: [S] int32.

    * training / prefill: cache=None.
    * decode: cache = (k_cache, v_cache) [B,Tmax,Hkv,D]; new rows written at
      cache_index (the caller mod-wraps for ring-buffer windowed caches);
      attention runs over the cache with `cache_positions` (defaults to
      arange) giving each row's absolute position for masking.
    * cross-attention: kv_override = precomputed (k, v) (no rope).
    """
    hd = cfg.hd
    b, s, _ = x.shape
    from .common import constrain
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, hd)
    if kv_override is None:
        k = (x @ p["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
        v = (x @ p["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
        # (perf iteration A3 tried pinning the SP->TP boundary here, before
        # the f32 rope segment — measured +3.7% collective bytes; reverted.)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)

    new_cache = None
    kv_pos = positions
    if cache is not None:
        k_cache, v_cache = cache
        clen = k_cache.shape[1]
        kw, vw, widx = k, v, cache_index
        if s >= clen and s > 1:
            # ring-buffer cache shorter than the prompt: keep only the tail,
            # ROLLED so that row r holds absolute position p ≡ r (mod clen)
            # — decode's ring_positions() relies on that alignment
            shift = s % clen
            kw = jnp.roll(k[:, -clen:], shift, axis=1)
            vw = jnp.roll(v[:, -clen:], shift, axis=1)
            widx = jnp.zeros((), jnp.int32)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, kw.astype(k_cache.dtype), widx, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, vw.astype(v_cache.dtype), widx, axis=1)
        new_cache = (k_cache, v_cache)
        if s == 1:
            # decode: attend over the cache; row positions mask garbage /
            # encode ring-buffer wraparound
            k, v = k_cache, v_cache
            kv_pos = cache_positions if cache_positions is not None \
                else jnp.arange(clen)
        # prefill (s > 1): attend over the fresh full-length k/v
    elif kv_override is not None:
        kv_pos = jnp.arange(k.shape[1])

    out = attend(q, k.astype(q.dtype), v.astype(q.dtype),
                 positions, kv_pos, spec, logit_cap)
    return out.reshape(b, s, cfg.num_heads * hd) @ p["wo"], new_cache
