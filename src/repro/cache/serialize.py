"""Exact JSON (de)serialization of compiled pipeline schedules.

Design rules:

* **Exact arithmetic** — every rational is encoded as `str(Fraction)`
  ("3/4", "1") and decoded back through `Fraction(str)`; round-tripping is
  loss-free, so "equals the claimed optimum" stays an `==` check.
* **Byte stability** — `dumps_canonical` emits sorted-key, tight-separator
  JSON with a trailing newline; serialize(deserialize(text)) == text, which
  the golden-schedule regression tests pin down.
* **Order fidelity** — tree-class vertex/edge addition order, round order,
  intra-round send order and per-edge path-allocation order are semantic
  (the simulator indexes capacity units by position), so those stay lists
  in original order; unordered maps (capacities, routing, path keys) are
  sorted for canonical output.

The artifact carries the compiler's *claimed* exact runtime (data_size=1)
so a consumer can re-simulate a loaded schedule and check achieved ==
claimed without recompiling anything.
"""
from __future__ import annotations

import json
from fractions import Fraction
from typing import Any, Dict, List, Tuple

from repro.core.arborescence import TreeClass
from repro.core.edge_split import SplitResult
from repro.core.graph import DiGraph, Edge
from repro.core.optimality import Optimality
from repro.core.plan import CompileStats
from repro.core.schedule import AllReduceSchedule, PipelineSchedule, Send

from .fingerprint import FORMAT_VERSION

SCHEDULE_FORMAT = "repro.schedule"
ALLREDUCE_FORMAT = "repro.allreduce"
STATS_FORMAT = "repro.compile_stats"
REPAIR_FORMAT = "repro.repair"
# Version of the *cache directory* schema (artifact payloads live at
# FORMAT_VERSION): v3 adds the per-artifact compile-stats sidecar and the
# flock-guarded index.  v5 adds transform-keyed `.repair` sidecars: a
# repaired artifact is stored under its natural (degraded-topology) key,
# and a `repair-...` sidecar keyed by base fingerprint + transform records
# `repair_time_s` and points at that artifact.  Readers accept older
# directories (no sidecar → no repair metadata).  v6 rides the artifact
# FORMAT_VERSION 2 → 3 bump (the kind vocabulary grew `alltoall`); the
# directory layout itself is unchanged.
CACHE_SCHEMA_VERSION = 6

# every kind a `repro.schedule` payload may carry (allreduce artifacts are
# the nested `repro.allreduce` format: an rs + an ag payload)
SCHEDULE_KINDS = ("allgather", "reduce_scatter", "broadcast", "reduce",
                  "alltoall")


class SerializationError(ValueError):
    pass


# ---------------------------------------------------------------------- #
# primitives
# ---------------------------------------------------------------------- #

def _enc_frac(f: Fraction) -> str:
    return str(Fraction(f))


def _dec_frac(s: str) -> Fraction:
    return Fraction(s)


def _enc_graph(g: DiGraph) -> Dict[str, Any]:
    return {
        "name": g.name,
        "num_nodes": g.num_nodes,
        "compute": sorted(g.compute),
        "cap": [[u, v, c] for (u, v), c in sorted(g.cap.items())],
    }


def _dec_graph(d: Dict[str, Any]) -> DiGraph:
    return DiGraph(d["num_nodes"], frozenset(d["compute"]),
                   {(u, v): c for u, v, c in d["cap"]}, d["name"])


# ---------------------------------------------------------------------- #
# schedule payloads
# ---------------------------------------------------------------------- #

def ensure_claimed(sched: PipelineSchedule, verify: bool = False) -> Fraction:
    """Fill (and return) the schedule's claimed exact runtime at data_size=1
    by running the round-accurate simulator once."""
    if sched.claimed_runtime is None:
        from repro.core import simulate as sim
        fn = {"allgather": sim.simulate_allgather,
              "reduce_scatter": sim.simulate_reduce_scatter,
              "broadcast": sim.simulate_broadcast,
              "reduce": sim.simulate_reduce,
              "alltoall": sim.simulate_alltoall}[sched.kind]
        sched.claimed_runtime = fn(sched, verify=verify).sim_time
    return sched.claimed_runtime


def schedule_to_payload(sched: PipelineSchedule,
                        verify: bool = False) -> Dict[str, Any]:
    claimed = ensure_claimed(sched, verify=verify)
    return {
        "format": SCHEDULE_FORMAT,
        "version": FORMAT_VERSION,
        "kind": sched.kind,
        "root": sched.root,
        "num_chunks": sched.num_chunks,
        "claimed_runtime": _enc_frac(claimed),
        "opt": {"inv_x_star": _enc_frac(sched.opt.inv_x_star),
                "U": _enc_frac(sched.opt.U), "k": sched.opt.k},
        "topo": _enc_graph(sched.topo),
        "dstar": _enc_graph(sched.dstar),
        "split": {
            "k": sched.split.k,
            "graph": _enc_graph(sched.split.graph),
            "original": _enc_graph(sched.split.original),
            "routing": [[u, t, sorted((w, c) for w, c in via.items())]
                        for (u, t), via in sorted(sched.split.routing.items())],
        },
        "classes": [{"root": c.root, "mult": c.mult, "verts": list(c.verts),
                     "edges": [[a, b] for a, b in c.edges]}
                    for c in sched.classes],
        "class_slot_offset": list(sched.class_slot_offset),
        "rounds": [[[s.src, s.dst, s.root, s.slot, s.cls] for s in rnd]
                   for rnd in sched.rounds],
        "path_assignment": [
            [cls, [e[0], e[1]], [[list(path), units] for path, units in alloc]]
            for (cls, e), alloc in sorted(sched.path_assignment.items())],
    }


def payload_to_schedule(d: Dict[str, Any]) -> PipelineSchedule:
    if d.get("format") != SCHEDULE_FORMAT:
        raise SerializationError(f"not a schedule payload: {d.get('format')!r}")
    if d.get("version") != FORMAT_VERSION:
        raise SerializationError(
            f"schedule format version {d.get('version')} != {FORMAT_VERSION}")
    if d.get("kind") not in SCHEDULE_KINDS:
        raise SerializationError(f"unknown schedule kind {d.get('kind')!r}")
    opt = Optimality(inv_x_star=_dec_frac(d["opt"]["inv_x_star"]),
                     U=_dec_frac(d["opt"]["U"]), k=d["opt"]["k"])
    sp = d["split"]
    split = SplitResult(
        graph=_dec_graph(sp["graph"]),
        routing={(u, t): {w: c for w, c in via}
                 for u, t, via in sp["routing"]},
        original=_dec_graph(sp["original"]),
        k=sp["k"])
    classes = [TreeClass(root=c["root"], mult=c["mult"],
                         verts=list(c["verts"]),
                         edges=[(a, b) for a, b in c["edges"]])
               for c in d["classes"]]
    rounds: List[List[Send]] = [
        [Send(src=s[0], dst=s[1], root=s[2], slot=s[3], cls=s[4])
         for s in rnd] for rnd in d["rounds"]]
    paths: Dict[Tuple[int, Edge], List[Tuple[Tuple[int, ...], int]]] = {
        (cls, (e[0], e[1])): [(tuple(path), units) for path, units in alloc]
        for cls, e, alloc in d["path_assignment"]}
    return PipelineSchedule(
        kind=d["kind"], topo=_dec_graph(d["topo"]),
        dstar=_dec_graph(d["dstar"]), opt=opt, classes=classes, split=split,
        num_chunks=d["num_chunks"], rounds=rounds,
        class_slot_offset=list(d["class_slot_offset"]),
        path_assignment=paths,
        claimed_runtime=_dec_frac(d["claimed_runtime"]))


def allreduce_to_payload(ar: AllReduceSchedule,
                         verify: bool = False) -> Dict[str, Any]:
    return {"format": ALLREDUCE_FORMAT, "version": FORMAT_VERSION,
            "rs": schedule_to_payload(ar.rs, verify=verify),
            "ag": schedule_to_payload(ar.ag, verify=verify)}


def payload_to_allreduce(d: Dict[str, Any]) -> AllReduceSchedule:
    if d.get("format") != ALLREDUCE_FORMAT:
        raise SerializationError(f"not an allreduce payload: {d.get('format')!r}")
    return AllReduceSchedule(rs=payload_to_schedule(d["rs"]),
                             ag=payload_to_schedule(d["ag"]))


# ---------------------------------------------------------------------- #
# compile-stats sidecar (cache schema v3)
# ---------------------------------------------------------------------- #

def stats_to_payload(art) -> Dict[str, Any]:
    """The `{key}.stats` sidecar payload for an artifact, or None when the
    artifact carries no per-stage instrumentation (e.g. it was built by a
    pre-v3 compiler or deserialized from a v2 cache directory)."""
    if isinstance(art, AllReduceSchedule):
        rs, ag = art.rs.compile_stats, art.ag.compile_stats
        if rs is None and ag is None:
            return None
        return {"format": STATS_FORMAT, "version": CACHE_SCHEMA_VERSION,
                "kind": "allreduce",
                "rs": rs.to_dict() if rs else None,
                "ag": ag.to_dict() if ag else None}
    if art.compile_stats is None:
        return None
    return {"format": STATS_FORMAT, "version": CACHE_SCHEMA_VERSION,
            "kind": art.kind, "stats": art.compile_stats.to_dict()}


def attach_stats(art, payload: Dict[str, Any]) -> None:
    """Re-attach a stats sidecar payload to a deserialized artifact (a
    malformed sidecar is ignored — stats are diagnostics, never needed for
    correctness)."""
    try:
        if payload.get("format") != STATS_FORMAT:
            return
        if isinstance(art, AllReduceSchedule):
            if payload.get("rs"):
                art.rs.compile_stats = CompileStats.from_dict(payload["rs"])
            if payload.get("ag"):
                art.ag.compile_stats = CompileStats.from_dict(payload["ag"])
        elif payload.get("stats"):
            art.compile_stats = CompileStats.from_dict(payload["stats"])
    except (KeyError, TypeError, ValueError):
        return


# ---------------------------------------------------------------------- #
# canonical text form
# ---------------------------------------------------------------------- #

def dumps_canonical(payload: Dict[str, Any]) -> str:
    return json.dumps(payload, sort_keys=True, separators=(",", ":")) + "\n"


def schedule_to_json(sched: PipelineSchedule, verify: bool = False) -> str:
    return dumps_canonical(schedule_to_payload(sched, verify=verify))


def schedule_from_json(text: str) -> PipelineSchedule:
    return payload_to_schedule(json.loads(text))


def allreduce_to_json(ar: AllReduceSchedule, verify: bool = False) -> str:
    return dumps_canonical(allreduce_to_payload(ar, verify=verify))


def allreduce_from_json(text: str) -> AllReduceSchedule:
    return payload_to_allreduce(json.loads(text))
