"""On-disk `ScheduleCache` — compile once, replay everywhere.

Artifacts live one-per-file under a root directory; the filename *is* the
cache key: ``{kind}-{graph_fp}-p{P}-k{K}[-r{root}]-{compiler_fp}.json``.
Because the compiler fingerprint is part of the key, editing any compiler
module silently invalidates every stale entry (old files are ignored, and
`prune_stale()` deletes them).

Hit path: read + deserialize, no compilation.  Miss path: delegate to the
`repro.core.schedule` compilers (resolved at call time through the module so
tests can monkeypatch/count them), attach the claimed exact runtime, write
atomically (tmp + rename), return.

An in-memory layer sits above the disk so repeated lookups inside one
process don't even touch the filesystem.

Cache schema v5 (artifact payloads stay at the v2 format):

* each artifact gets a ``{key}.stats`` sidecar with the compiler's
  per-stage `CompileStats` (loaded back onto hits);
* all mutations (store, evict, prune, clear) run under an ``flock`` on
  ``.lock`` and maintain an advisory ``.index`` JSON of resident entries,
  so concurrent writer processes never interleave an eviction scan with a
  write or corrupt the index.  Reads stay lock-free (renames are atomic).
* repaired artifacts (v5) get a ``repair-...`` sidecar keyed by the *base*
  graph fingerprint plus the transform text.  The sidecar records the
  `RepairReport` (``repair_time_s`` et al.) and points at the repaired
  artifact, which lives under its natural degraded-topology key — so a
  later cold compile of the degraded spec hits the byte-identical repaired
  entry, and a later repair of the same (base, transform) pair returns
  without touching the compiler.  Dangling sidecars (artifact evicted)
  degrade to a miss.
  Directories written by an older cache load fine — no sidecar means no
  stats / no repair metadata.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import tempfile
from fractions import Fraction
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Union

try:
    import fcntl
except ImportError:  # pragma: no cover — non-POSIX fallback, advisory only
    fcntl = None

from repro.core import schedule as schedule_mod
from repro.core.graph import DiGraph
from repro.core.schedule import AllReduceSchedule, PipelineSchedule

from .fingerprint import (compiler_fingerprint, repair_cache_key,
                          schedule_cache_key)
from .serialize import (CACHE_SCHEMA_VERSION, REPAIR_FORMAT,
                        allreduce_from_json, allreduce_to_json, attach_stats,
                        schedule_from_json, schedule_to_json,
                        stats_to_payload)

Artifact = Union[PipelineSchedule, AllReduceSchedule]

INDEX_FORMAT = "repro.schedule_cache_index"


def default_cache_dir() -> str:
    """$REPRO_SCHEDULE_CACHE, else ~/.cache/repro/schedules."""
    env = os.environ.get("REPRO_SCHEDULE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "schedules")


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    puts: int = 0
    evictions: int = 0

    def describe(self) -> str:
        return (f"hits={self.hits} misses={self.misses} puts={self.puts} "
                f"evictions={self.evictions}")


class ScheduleCache:
    """Content-addressed on-disk store of compiled schedule artifacts.

    One artifact per file; the filename is the cache key (kind × graph
    fingerprint × chunk count × compiler fingerprint).  `max_bytes` turns on
    size-capped LRU eviction: every disk hit refreshes the artifact's mtime,
    and after each write the least-recently-used artifacts are deleted until
    the directory fits the cap (the just-written artifact is never evicted,
    so a single oversized schedule still caches)."""

    def __init__(self, root: Union[str, Path, None] = None,
                 compiler_fp: Optional[str] = None,
                 verify_on_compile: bool = False,
                 max_bytes: Optional[int] = None):
        self.root = Path(root if root is not None else default_cache_dir())
        self.root.mkdir(parents=True, exist_ok=True)
        self.compiler_fp = compiler_fp or compiler_fingerprint()
        self.verify_on_compile = verify_on_compile
        self.max_bytes = max_bytes
        self.stats = CacheStats()
        self._memory: Dict[str, Artifact] = {}

    # ------------------------------------------------------------------ #
    # key / path plumbing
    # ------------------------------------------------------------------ #

    def key(self, kind: str, topo: DiGraph, num_chunks: int,
            fixed_k: Optional[int] = None, root: Optional[int] = None) -> str:
        return schedule_cache_key(kind, topo, num_chunks, fixed_k=fixed_k,
                                  root=root, compiler_fp=self.compiler_fp)

    def path_for(self, key: str) -> Path:
        return self.root / f"{key}.json"

    def stats_path_for(self, key: str) -> Path:
        """The compile-stats sidecar (no .json suffix, so artifact globs
        and the LRU size accounting never see it)."""
        return self.root / f"{key}.stats"

    # ------------------------------------------------------------------ #
    # cross-process serialization: flock + advisory index
    # ------------------------------------------------------------------ #

    @contextlib.contextmanager
    def _locked(self):
        """Exclusive flock over the cache directory's mutations.  Advisory:
        readers never take it (atomic renames keep reads torn-write-free),
        and on platforms without fcntl it degrades to a no-op."""
        if fcntl is None:
            yield
            return
        with open(self.root / ".lock", "a+") as fh:
            fcntl.flock(fh, fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(fh, fcntl.LOCK_UN)

    def _index_path(self) -> Path:
        return self.root / ".index"

    def _read_index(self) -> Dict[str, Dict]:
        """The advisory entry index ({key: {bytes, kind}}).  Never trusted
        for correctness — a missing or corrupt index is just rebuilt."""
        try:
            doc = json.loads(self._index_path().read_text())
            if doc.get("format") == INDEX_FORMAT:
                return dict(doc.get("entries", {}))
        except (OSError, ValueError):
            pass
        return {}

    def _write_index(self, entries: Dict[str, Dict]) -> None:
        doc = {"format": INDEX_FORMAT, "version": CACHE_SCHEMA_VERSION,
               "compiler": self.compiler_fp, "entries": entries}
        self._atomic_write(self._index_path(), json.dumps(doc, sort_keys=True))

    def _index_update(self, add: Optional[Dict[str, Dict]] = None,
                      drop: Sequence[str] = ()) -> None:
        entries = self._read_index()
        for key in drop:
            entries.pop(key, None)
        for key, info in (add or {}).items():
            entries[key] = info
        self._write_index(entries)

    def index(self) -> Dict[str, Dict]:
        """Advisory {key: {bytes, kind}} of resident artifacts, maintained
        under the flock by every writer."""
        return self._read_index()

    def rebuild_index(self) -> Dict[str, Dict]:
        """Reconstruct the index from the directory contents (run under the
        lock so a concurrent writer can't interleave)."""
        with self._locked():
            entries = {}
            for p in self.root.glob("*.json"):
                try:
                    entries[p.stem] = {"bytes": p.stat().st_size,
                                       "kind": p.stem.split("-", 1)[0]}
                except OSError:
                    continue
            self._write_index(entries)
            return entries

    def _unlink_entry(self, key: str) -> None:
        """Delete an artifact and its stats sidecar (lock held by caller
        when racing writers matter)."""
        for path in (self.path_for(key), self.stats_path_for(key)):
            try:
                path.unlink()
            except OSError:
                pass

    def _load(self, key: str, allreduce: bool) -> Optional[Artifact]:
        if key in self._memory:
            self.stats.hits += 1
            self._touch(key)          # memory hits still count as LRU use
            return self._memory[key]
        path = self.path_for(key)
        if not path.exists():
            self.stats.misses += 1
            return None
        try:
            text = path.read_text()
            art: Artifact = (allreduce_from_json(text) if allreduce
                             else schedule_from_json(text))
        except Exception as e:  # noqa: BLE001 — any unreadable artifact
            # torn write / corrupt artifact: drop it and recompile rather
            # than brick every consumer of this cache directory
            import warnings
            warnings.warn(f"discarding unreadable schedule artifact "
                          f"{path.name}: {e}")
            with self._locked():
                self._unlink_entry(key)
                self._index_update(drop=[key])
            self.stats.misses += 1
            return None
        stats_path = self.stats_path_for(key)
        if stats_path.exists():
            try:
                attach_stats(art, json.loads(stats_path.read_text()))
            except (OSError, ValueError):
                pass                  # sidecar is diagnostics only
        self._touch(key)              # LRU recency = file mtime
        self._memory[key] = art
        self.stats.hits += 1
        return art

    def _touch(self, key: str) -> None:
        if self.max_bytes is None:
            return
        try:
            os.utime(self.path_for(key))
        except OSError:
            pass

    def _store(self, key: str, art: Artifact) -> None:
        text = (allreduce_to_json(art) if isinstance(art, AllReduceSchedule)
                else schedule_to_json(art))
        stats_payload = stats_to_payload(art)
        path = self.path_for(key)
        with self._locked():
            self._atomic_write(path, text)
            if stats_payload is not None:
                self._atomic_write(self.stats_path_for(key),
                                   json.dumps(stats_payload, sort_keys=True)
                                   + "\n")
            kind = ("allreduce" if isinstance(art, AllReduceSchedule)
                    else art.kind)
            self._index_update(add={key: {"bytes": len(text), "kind": kind}})
            if self.max_bytes is not None:
                self._evict_lru(keep=path)
        self._memory[key] = art
        self.stats.puts += 1

    def _atomic_write(self, path: Path, text: str) -> None:
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(text)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def size_bytes(self) -> int:
        """Total bytes of artifacts currently on disk (concurrent deletions
        by other processes are skipped, like in `_evict_lru`)."""
        total = 0
        for p in self.root.glob("*.json"):
            try:
                total += p.stat().st_size
            except OSError:
                continue
        return total

    def _evict_lru(self, keep: Path) -> int:
        """Delete least-recently-used artifacts (and their stats sidecars)
        until the directory fits `max_bytes`.  `keep` (the artifact just
        written) is exempt.  Caller holds the flock."""
        files = []
        for p in self.root.glob("*.json"):
            try:
                st = p.stat()
            except OSError:
                continue
            files.append((st.st_mtime, st.st_size, p))
        total = sum(sz for _, sz, _ in files)
        removed = 0
        dropped: List[str] = []
        for _, sz, p in sorted(files):
            if total <= self.max_bytes:
                break
            if p == keep:
                continue
            try:
                p.unlink()
            except OSError:
                continue
            try:
                self.stats_path_for(p.stem).unlink()
            except OSError:
                pass
            self._memory.pop(p.stem, None)
            dropped.append(p.stem)
            total -= sz
            removed += 1
            self.stats.evictions += 1
        if dropped:
            self._index_update(drop=dropped)
        return removed

    # ------------------------------------------------------------------ #
    # cached compilers
    # ------------------------------------------------------------------ #

    def allgather(self, topo: DiGraph, num_chunks: int = 8,
                  fixed_k: Optional[int] = None) -> PipelineSchedule:
        key = self.key("allgather", topo, num_chunks, fixed_k)
        hit = self._load(key, allreduce=False)
        if hit is not None:
            return hit
        sched = schedule_mod.compile_allgather(
            topo, num_chunks=num_chunks, fixed_k=fixed_k,
            verify=self.verify_on_compile)
        self._store(key, sched)
        return sched

    def reduce_scatter(self, topo: DiGraph, num_chunks: int = 8,
                       fixed_k: Optional[int] = None) -> PipelineSchedule:
        key = self.key("reduce_scatter", topo, num_chunks, fixed_k)
        hit = self._load(key, allreduce=False)
        if hit is not None:
            return hit
        sched = schedule_mod.compile_reduce_scatter(
            topo, num_chunks=num_chunks, fixed_k=fixed_k,
            verify=self.verify_on_compile)
        self._store(key, sched)
        return sched

    def alltoall(self, topo: DiGraph, num_chunks: int = 8,
                 fixed_k: Optional[int] = None) -> PipelineSchedule:
        key = self.key("alltoall", topo, num_chunks, fixed_k)
        hit = self._load(key, allreduce=False)
        if hit is not None:
            return hit
        sched = schedule_mod.compile_alltoall(
            topo, num_chunks=num_chunks, fixed_k=fixed_k,
            verify=self.verify_on_compile)
        self._store(key, sched)
        return sched

    def allreduce(self, topo: DiGraph, num_chunks: int = 8,
                  fixed_k: Optional[int] = None) -> AllReduceSchedule:
        key = self.key("allreduce", topo, num_chunks, fixed_k)
        hit = self._load(key, allreduce=True)
        if hit is not None:
            return hit
        ar = schedule_mod.compile_allreduce(
            topo, num_chunks=num_chunks, fixed_k=fixed_k,
            verify=self.verify_on_compile)
        self._store(key, ar)
        return ar

    def broadcast(self, topo: DiGraph, root: int,
                  num_chunks: int = 8) -> PipelineSchedule:
        key = self.key("broadcast", topo, num_chunks, root=root)
        hit = self._load(key, allreduce=False)
        if hit is not None:
            return hit
        sched = schedule_mod.compile_broadcast(topo, root=root,
                                               num_chunks=num_chunks,
                                               verify=self.verify_on_compile)
        self._store(key, sched)
        return sched

    def reduce(self, topo: DiGraph, root: int,
               num_chunks: int = 8) -> PipelineSchedule:
        key = self.key("reduce", topo, num_chunks, root=root)
        hit = self._load(key, allreduce=False)
        if hit is not None:
            return hit
        sched = schedule_mod.compile_reduce(topo, root=root,
                                            num_chunks=num_chunks,
                                            verify=self.verify_on_compile)
        self._store(key, sched)
        return sched

    def family(self, topo: DiGraph, kinds: Sequence[str],
               num_chunks: int = 8, fixed_k: Optional[int] = None,
               root: Optional[int] = None,
               timings: Optional[Dict[str, float]] = None
               ) -> Dict[str, Artifact]:
        """Cached `plan.compile_family`: load every hit, then compile all
        remaining kinds **together** so the misses share solve/split/pack
        products instead of compiling independently.  Keys are identical to
        the per-kind methods', so family- and per-kind lookups share
        entries.  Rooted kinds need `root`; `fixed_k` applies to the
        allgather family only.  A `timings` dict receives per-kind wall
        seconds (load time for hits, marginal compile time for misses)."""
        import time as _time
        out: Dict[str, Artifact] = {}
        missing: List[tuple] = []
        for kind in kinds:
            rooted = kind in ("broadcast", "reduce")
            key = self.key(kind, topo, num_chunks,
                           fixed_k=None if rooted else fixed_k,
                           root=root if rooted else None)
            t0 = _time.perf_counter()
            hit = self._load(key, allreduce=kind == "allreduce")
            if hit is not None:
                out[kind] = hit
                if timings is not None:
                    timings[kind] = _time.perf_counter() - t0
            else:
                missing.append((kind, key))
        if missing:
            from repro.core import plan as plan_mod
            compiled = plan_mod.compile_family(
                topo, kinds=[k for k, _ in missing], num_chunks=num_chunks,
                root=root, fixed_k=fixed_k, verify=self.verify_on_compile,
                timings=timings)
            for kind, key in missing:
                self._store(key, compiled[kind])
                out[kind] = compiled[kind]
        return out

    # ------------------------------------------------------------------ #
    # repaired artifacts (schema v5)
    # ------------------------------------------------------------------ #

    @staticmethod
    def artifact_meta(art: Artifact) -> tuple:
        """(kind, num_chunks, root) of an artifact — the key coordinates
        shared by the base schedule and any repair of it."""
        if isinstance(art, AllReduceSchedule):
            return "allreduce", art.rs.num_chunks, None
        return art.kind, art.num_chunks, art.root

    def repair_key(self, base_art: Artifact, transform) -> str:
        kind, num_chunks, root = self.artifact_meta(base_art)
        return repair_cache_key(kind, base_art.topo, transform, num_chunks,
                                root=root, compiler_fp=self.compiler_fp)

    def repair_path_for(self, key: str) -> Path:
        """The transform-keyed repair sidecar (no .json suffix, so artifact
        globs and the LRU size accounting never see it)."""
        return self.root / f"{key}.repair"

    def repaired(self, base_art: Artifact, transform):
        """Look up a cached repair of `base_art` under `transform`.

        Returns ``(artifact, meta)`` on a hit — `meta` is the sidecar dict
        whose ``report`` entry is the original `RepairReport.to_dict()` —
        or ``None`` when there is no sidecar or the artifact it points at
        has been evicted."""
        rkey = self.repair_key(base_art, transform)
        path = self.repair_path_for(rkey)
        try:
            meta = json.loads(path.read_text())
        except (OSError, ValueError):
            return None
        if meta.get("format") != REPAIR_FORMAT:
            return None
        kind = meta.get("kind")
        art = self._load(meta.get("artifact_key", ""),
                         allreduce=kind == "allreduce")
        if art is None:
            return None
        return art, meta

    def put_repaired(self, base_art: Artifact, transform,
                     repaired_art: Artifact, report) -> str:
        """Store a repaired artifact plus its transform-keyed sidecar.

        The artifact itself goes under its natural degraded-topology key
        (`_store`), so ordinary `schedule()` lookups of the degraded spec
        hit it too; the sidecar ties (base fingerprint, transform) to that
        key and carries the repair report.  Returns the sidecar key."""
        kind, num_chunks, root = self.artifact_meta(base_art)
        akey = self.key(kind, repaired_art.topo, num_chunks,
                        root=None if root is None else repaired_art.root)
        self._store(akey, repaired_art)
        rkey = self.repair_key(base_art, transform)
        doc = {"format": REPAIR_FORMAT, "version": CACHE_SCHEMA_VERSION,
               "kind": kind, "artifact_key": akey,
               "base_fingerprint": base_art.topo.fingerprint(),
               "transform": str(transform),
               "report": report.to_dict() if report is not None else None}
        with self._locked():
            self._atomic_write(self.repair_path_for(rkey),
                               json.dumps(doc, sort_keys=True) + "\n")
        return rkey

    # ------------------------------------------------------------------ #
    # maintenance
    # ------------------------------------------------------------------ #

    def entries(self) -> List[str]:
        return sorted(p.stem for p in self.root.glob("*.json"))

    def prune_stale(self) -> int:
        """Delete artifacts written by a different compiler fingerprint."""
        removed = 0
        with self._locked():
            dropped = []
            for p in self.root.glob("*.json"):
                if not p.stem.endswith(self.compiler_fp):
                    self._unlink_entry(p.stem)
                    dropped.append(p.stem)
                    removed += 1
            for p in self.root.glob("*.repair"):
                if not p.stem.endswith(self.compiler_fp):
                    try:
                        p.unlink()
                    except OSError:
                        pass
            if dropped:
                self._index_update(drop=dropped)
        return removed

    def clear(self) -> None:
        with self._locked():
            for p in list(self.root.glob("*.json")) + \
                    list(self.root.glob("*.stats")) + \
                    list(self.root.glob("*.repair")):
                try:
                    p.unlink()
                except OSError:
                    pass
            self._write_index({})
        self._memory.clear()

    def describe(self) -> str:
        return (f"ScheduleCache[{self.root}] compiler={self.compiler_fp} "
                f"entries={len(self.entries())} {self.stats.describe()}")
