"""On-disk `ScheduleCache` — compile once, replay everywhere.

Artifacts live one-per-file under a root directory; the filename *is* the
cache key: ``{kind}-{graph_fp}-p{P}-k{K}[-r{root}]-{compiler_fp}.json``.
Because the compiler fingerprint is part of the key, editing any compiler
module silently invalidates every stale entry (old files are ignored, and
`prune_stale()` deletes them).

Hit path: read + deserialize, no compilation.  Miss path: delegate to the
`repro.core.schedule` compilers (resolved at call time through the module so
tests can monkeypatch/count them), attach the claimed exact runtime, write
atomically (tmp + rename), return.

An in-memory layer sits above the disk so repeated lookups inside one
process don't even touch the filesystem.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from fractions import Fraction
from pathlib import Path
from typing import Dict, List, Optional, Union

from repro.core import schedule as schedule_mod
from repro.core.graph import DiGraph
from repro.core.schedule import AllReduceSchedule, PipelineSchedule

from .fingerprint import compiler_fingerprint, schedule_cache_key
from .serialize import (allreduce_from_json, allreduce_to_json,
                        schedule_from_json, schedule_to_json)

Artifact = Union[PipelineSchedule, AllReduceSchedule]


def default_cache_dir() -> str:
    """$REPRO_SCHEDULE_CACHE, else ~/.cache/repro/schedules."""
    env = os.environ.get("REPRO_SCHEDULE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "schedules")


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    puts: int = 0
    evictions: int = 0

    def describe(self) -> str:
        return (f"hits={self.hits} misses={self.misses} puts={self.puts} "
                f"evictions={self.evictions}")


class ScheduleCache:
    """Content-addressed on-disk store of compiled schedule artifacts.

    One artifact per file; the filename is the cache key (kind × graph
    fingerprint × chunk count × compiler fingerprint).  `max_bytes` turns on
    size-capped LRU eviction: every disk hit refreshes the artifact's mtime,
    and after each write the least-recently-used artifacts are deleted until
    the directory fits the cap (the just-written artifact is never evicted,
    so a single oversized schedule still caches)."""

    def __init__(self, root: Union[str, Path, None] = None,
                 compiler_fp: Optional[str] = None,
                 verify_on_compile: bool = False,
                 max_bytes: Optional[int] = None):
        self.root = Path(root if root is not None else default_cache_dir())
        self.root.mkdir(parents=True, exist_ok=True)
        self.compiler_fp = compiler_fp or compiler_fingerprint()
        self.verify_on_compile = verify_on_compile
        self.max_bytes = max_bytes
        self.stats = CacheStats()
        self._memory: Dict[str, Artifact] = {}

    # ------------------------------------------------------------------ #
    # key / path plumbing
    # ------------------------------------------------------------------ #

    def key(self, kind: str, topo: DiGraph, num_chunks: int,
            fixed_k: Optional[int] = None, root: Optional[int] = None) -> str:
        return schedule_cache_key(kind, topo, num_chunks, fixed_k=fixed_k,
                                  root=root, compiler_fp=self.compiler_fp)

    def path_for(self, key: str) -> Path:
        return self.root / f"{key}.json"

    def _load(self, key: str, allreduce: bool) -> Optional[Artifact]:
        if key in self._memory:
            self.stats.hits += 1
            self._touch(key)          # memory hits still count as LRU use
            return self._memory[key]
        path = self.path_for(key)
        if not path.exists():
            self.stats.misses += 1
            return None
        try:
            text = path.read_text()
            art: Artifact = (allreduce_from_json(text) if allreduce
                             else schedule_from_json(text))
        except Exception as e:  # noqa: BLE001 — any unreadable artifact
            # torn write / corrupt artifact: drop it and recompile rather
            # than brick every consumer of this cache directory
            import warnings
            warnings.warn(f"discarding unreadable schedule artifact "
                          f"{path.name}: {e}")
            try:
                path.unlink()
            except OSError:
                pass
            self.stats.misses += 1
            return None
        self._touch(key)              # LRU recency = file mtime
        self._memory[key] = art
        self.stats.hits += 1
        return art

    def _touch(self, key: str) -> None:
        if self.max_bytes is None:
            return
        try:
            os.utime(self.path_for(key))
        except OSError:
            pass

    def _store(self, key: str, art: Artifact) -> None:
        text = (allreduce_to_json(art) if isinstance(art, AllReduceSchedule)
                else schedule_to_json(art))
        path = self.path_for(key)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(text)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        self._memory[key] = art
        self.stats.puts += 1
        if self.max_bytes is not None:
            self._evict_lru(keep=path)

    def size_bytes(self) -> int:
        """Total bytes of artifacts currently on disk (concurrent deletions
        by other processes are skipped, like in `_evict_lru`)."""
        total = 0
        for p in self.root.glob("*.json"):
            try:
                total += p.stat().st_size
            except OSError:
                continue
        return total

    def _evict_lru(self, keep: Path) -> int:
        """Delete least-recently-used artifacts until the directory fits
        `max_bytes`.  `keep` (the artifact just written) is exempt."""
        files = []
        for p in self.root.glob("*.json"):
            try:
                st = p.stat()
            except OSError:
                continue
            files.append((st.st_mtime, st.st_size, p))
        total = sum(sz for _, sz, _ in files)
        removed = 0
        for _, sz, p in sorted(files):
            if total <= self.max_bytes:
                break
            if p == keep:
                continue
            try:
                p.unlink()
            except OSError:
                continue
            self._memory.pop(p.stem, None)
            total -= sz
            removed += 1
            self.stats.evictions += 1
        return removed

    # ------------------------------------------------------------------ #
    # cached compilers
    # ------------------------------------------------------------------ #

    def allgather(self, topo: DiGraph, num_chunks: int = 8,
                  fixed_k: Optional[int] = None) -> PipelineSchedule:
        key = self.key("allgather", topo, num_chunks, fixed_k)
        hit = self._load(key, allreduce=False)
        if hit is not None:
            return hit
        sched = schedule_mod.compile_allgather(
            topo, num_chunks=num_chunks, fixed_k=fixed_k,
            verify=self.verify_on_compile)
        self._store(key, sched)
        return sched

    def reduce_scatter(self, topo: DiGraph, num_chunks: int = 8,
                       fixed_k: Optional[int] = None) -> PipelineSchedule:
        key = self.key("reduce_scatter", topo, num_chunks, fixed_k)
        hit = self._load(key, allreduce=False)
        if hit is not None:
            return hit
        sched = schedule_mod.compile_reduce_scatter(
            topo, num_chunks=num_chunks, fixed_k=fixed_k,
            verify=self.verify_on_compile)
        self._store(key, sched)
        return sched

    def allreduce(self, topo: DiGraph, num_chunks: int = 8,
                  fixed_k: Optional[int] = None) -> AllReduceSchedule:
        key = self.key("allreduce", topo, num_chunks, fixed_k)
        hit = self._load(key, allreduce=True)
        if hit is not None:
            return hit
        ar = schedule_mod.compile_allreduce(
            topo, num_chunks=num_chunks, fixed_k=fixed_k,
            verify=self.verify_on_compile)
        self._store(key, ar)
        return ar

    def broadcast(self, topo: DiGraph, root: int,
                  num_chunks: int = 8) -> PipelineSchedule:
        key = self.key("broadcast", topo, num_chunks, root=root)
        hit = self._load(key, allreduce=False)
        if hit is not None:
            return hit
        sched = schedule_mod.compile_broadcast(topo, root=root,
                                               num_chunks=num_chunks,
                                               verify=self.verify_on_compile)
        self._store(key, sched)
        return sched

    def reduce(self, topo: DiGraph, root: int,
               num_chunks: int = 8) -> PipelineSchedule:
        key = self.key("reduce", topo, num_chunks, root=root)
        hit = self._load(key, allreduce=False)
        if hit is not None:
            return hit
        sched = schedule_mod.compile_reduce(topo, root=root,
                                            num_chunks=num_chunks,
                                            verify=self.verify_on_compile)
        self._store(key, sched)
        return sched

    # ------------------------------------------------------------------ #
    # maintenance
    # ------------------------------------------------------------------ #

    def entries(self) -> List[str]:
        return sorted(p.stem for p in self.root.glob("*.json"))

    def prune_stale(self) -> int:
        """Delete artifacts written by a different compiler fingerprint."""
        removed = 0
        for p in self.root.glob("*.json"):
            if not p.stem.endswith(self.compiler_fp):
                p.unlink()
                removed += 1
        return removed

    def clear(self) -> None:
        for p in self.root.glob("*.json"):
            p.unlink()
        self._memory.clear()

    def describe(self) -> str:
        return (f"ScheduleCache[{self.root}] compiler={self.compiler_fp} "
                f"entries={len(self.entries())} {self.stats.describe()}")
