"""On-disk `ScheduleCache` — compile once, replay everywhere.

Artifacts live one-per-file under a root directory; the filename *is* the
cache key: ``{kind}-{graph_fp}-p{P}-k{K}[-r{root}]-{compiler_fp}.json``.
Because the compiler fingerprint is part of the key, editing any compiler
module silently invalidates every stale entry (old files are ignored, and
`prune_stale()` deletes them).

Hit path: read + deserialize, no compilation.  Miss path: delegate to the
`repro.core.schedule` compilers (resolved at call time through the module so
tests can monkeypatch/count them), attach the claimed exact runtime, write
atomically (tmp + rename), return.

An in-memory layer sits above the disk so repeated lookups inside one
process don't even touch the filesystem.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from fractions import Fraction
from pathlib import Path
from typing import Dict, List, Optional, Union

from repro.core import schedule as schedule_mod
from repro.core.graph import DiGraph
from repro.core.schedule import AllReduceSchedule, PipelineSchedule

from .fingerprint import compiler_fingerprint, schedule_cache_key
from .serialize import (allreduce_from_json, allreduce_to_json,
                        schedule_from_json, schedule_to_json)

Artifact = Union[PipelineSchedule, AllReduceSchedule]


def default_cache_dir() -> str:
    """$REPRO_SCHEDULE_CACHE, else ~/.cache/repro/schedules."""
    env = os.environ.get("REPRO_SCHEDULE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "schedules")


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    puts: int = 0

    def describe(self) -> str:
        return f"hits={self.hits} misses={self.misses} puts={self.puts}"


class ScheduleCache:
    def __init__(self, root: Union[str, Path, None] = None,
                 compiler_fp: Optional[str] = None,
                 verify_on_compile: bool = False):
        self.root = Path(root if root is not None else default_cache_dir())
        self.root.mkdir(parents=True, exist_ok=True)
        self.compiler_fp = compiler_fp or compiler_fingerprint()
        self.verify_on_compile = verify_on_compile
        self.stats = CacheStats()
        self._memory: Dict[str, Artifact] = {}

    # ------------------------------------------------------------------ #
    # key / path plumbing
    # ------------------------------------------------------------------ #

    def key(self, kind: str, topo: DiGraph, num_chunks: int,
            fixed_k: Optional[int] = None, root: Optional[int] = None) -> str:
        return schedule_cache_key(kind, topo, num_chunks, fixed_k=fixed_k,
                                  root=root, compiler_fp=self.compiler_fp)

    def path_for(self, key: str) -> Path:
        return self.root / f"{key}.json"

    def _load(self, key: str, allreduce: bool) -> Optional[Artifact]:
        if key in self._memory:
            self.stats.hits += 1
            return self._memory[key]
        path = self.path_for(key)
        if not path.exists():
            self.stats.misses += 1
            return None
        try:
            text = path.read_text()
            art: Artifact = (allreduce_from_json(text) if allreduce
                             else schedule_from_json(text))
        except Exception as e:  # noqa: BLE001 — any unreadable artifact
            # torn write / corrupt artifact: drop it and recompile rather
            # than brick every consumer of this cache directory
            import warnings
            warnings.warn(f"discarding unreadable schedule artifact "
                          f"{path.name}: {e}")
            try:
                path.unlink()
            except OSError:
                pass
            self.stats.misses += 1
            return None
        self._memory[key] = art
        self.stats.hits += 1
        return art

    def _store(self, key: str, art: Artifact) -> None:
        text = (allreduce_to_json(art) if isinstance(art, AllReduceSchedule)
                else schedule_to_json(art))
        path = self.path_for(key)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(text)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        self._memory[key] = art
        self.stats.puts += 1

    # ------------------------------------------------------------------ #
    # cached compilers
    # ------------------------------------------------------------------ #

    def allgather(self, topo: DiGraph, num_chunks: int = 8,
                  fixed_k: Optional[int] = None) -> PipelineSchedule:
        key = self.key("allgather", topo, num_chunks, fixed_k)
        hit = self._load(key, allreduce=False)
        if hit is not None:
            return hit
        sched = schedule_mod.compile_allgather(
            topo, num_chunks=num_chunks, fixed_k=fixed_k,
            verify=self.verify_on_compile)
        self._store(key, sched)
        return sched

    def reduce_scatter(self, topo: DiGraph, num_chunks: int = 8,
                       fixed_k: Optional[int] = None) -> PipelineSchedule:
        key = self.key("reduce_scatter", topo, num_chunks, fixed_k)
        hit = self._load(key, allreduce=False)
        if hit is not None:
            return hit
        sched = schedule_mod.compile_reduce_scatter(
            topo, num_chunks=num_chunks, fixed_k=fixed_k,
            verify=self.verify_on_compile)
        self._store(key, sched)
        return sched

    def allreduce(self, topo: DiGraph, num_chunks: int = 8,
                  fixed_k: Optional[int] = None) -> AllReduceSchedule:
        key = self.key("allreduce", topo, num_chunks, fixed_k)
        hit = self._load(key, allreduce=True)
        if hit is not None:
            return hit
        ar = schedule_mod.compile_allreduce(
            topo, num_chunks=num_chunks, fixed_k=fixed_k,
            verify=self.verify_on_compile)
        self._store(key, ar)
        return ar

    def broadcast(self, topo: DiGraph, root: int,
                  num_chunks: int = 8) -> PipelineSchedule:
        key = self.key("broadcast", topo, num_chunks, root=root)
        hit = self._load(key, allreduce=False)
        if hit is not None:
            return hit
        sched = schedule_mod.compile_broadcast(topo, root=root,
                                               num_chunks=num_chunks)
        self._store(key, sched)
        return sched

    # ------------------------------------------------------------------ #
    # maintenance
    # ------------------------------------------------------------------ #

    def entries(self) -> List[str]:
        return sorted(p.stem for p in self.root.glob("*.json"))

    def prune_stale(self) -> int:
        """Delete artifacts written by a different compiler fingerprint."""
        removed = 0
        for p in self.root.glob("*.json"):
            if not p.stem.endswith(self.compiler_fp):
                p.unlink()
                removed += 1
        return removed

    def clear(self) -> None:
        for p in self.root.glob("*.json"):
            p.unlink()
        self._memory.clear()

    def describe(self) -> str:
        return (f"ScheduleCache[{self.root}] compiler={self.compiler_fp} "
                f"entries={len(self.entries())} {self.stats.describe()}")
