# Schedule artifact subsystem: content-addressed fingerprints, exact-Fraction
# JSON serialization of compiled pipeline schedules, an on-disk cache with
# compiler-versioned invalidation, and the topology-zoo sweep driver.
from .fingerprint import (FORMAT_VERSION, compiler_fingerprint,  # noqa: F401
                          graph_fingerprint, schedule_cache_key)
from .serialize import (CACHE_SCHEMA_VERSION, SCHEDULE_KINDS,  # noqa: F401
                        SerializationError, allreduce_from_json,
                        allreduce_to_json, attach_stats, dumps_canonical,
                        ensure_claimed, schedule_from_json, schedule_to_json,
                        stats_to_payload)
from .store import CacheStats, ScheduleCache, default_cache_dir  # noqa: F401
from .sweep import (ALLTOALL_CHUNKS, COLLECTIVES,  # noqa: F401
                    FIXED_K_COLLECTIVES, LARGE_NAMES, PERF_GATE_NAMES,
                    SMOKE_NAMES, claim_mismatches, default_out_path,
                    run_sweep, sweep_one, sweep_registry)
