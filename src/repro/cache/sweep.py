"""Topology-zoo sweep: compile + simulate + verify the full collective
family on every topology, emit `BENCH_schedules.json` — the repo's
schedule-quality scoreboard.

Every (topology, collective) entry records compile time, the exact optimal
bound for that collective, the schedule's claimed pipelined runtime, the
re-simulated achieved runtime and their exact ratio
(``achieved_over_claimed`` must be "1": the verifier replays every chunk, so
a schedule that does not reproduce its claim fails the sweep).
``achieved_over_lb`` tracks convergence to the asymptotic bound as the chunk
count grows.

Collectives swept (``--collectives`` selects a subset):

  allgather / reduce_scatter — §2.1-2.3 construction and its transpose dual
  broadcast / reduce         — Appendix A rooted trees (root = first compute
                               node) and the edge-reversed reduction
  allreduce                  — Appendix B RS+AG composition, cached as one
                               artifact
  alltoall                   — per-source pruned scatter over the allgather
                               family's packed trees (swept at P = 1: the
                               N−1 destination blocks already fill the
                               pipeline, so re-chunking buys nothing)

The sweep compiles each topology's collectives **as one family**
(`plan.compile_family` / `ScheduleCache.family`): the §2.1 solve and the
split/pack products are shared across kinds (allreduce reuses its
allgather / reduce-scatter siblings outright), byte-identical to the
per-kind compilers.  Each row's ``compile_time_s`` is that kind's
*marginal* wall time — shared stage work is charged to the kind that
triggered it, so the rows of one topology sum to its family compile time.

Every row carries the staged compiler's per-stage record (BENCH v6
``compile_stats``: a ``[{stage, seconds, probes, augments}]`` list in
pipeline order) alongside the total ``compile_time_s``, plus the summed
oracle-engine work counters (``oracle_probes`` / ``oracle_augments``:
maxflow calls and augmenting paths over the stages that produced the
artifact), so perf work can see *which* stage moved and whether oracle
reuse is paying off.  Note that an artifact emitted from shared plan
products reports the shared stages' times/counters (the work that
*produced* it), which can exceed its own marginal ``compile_time_s``.

``--repair`` (BENCH v5) adds a ``repair`` section: every swept row whose
spec carries a transform (``*_failed`` / ``*_degraded`` zoo rows,
transformed --topology specs) is *also* produced by online schedule repair
(`repro.core.repair`) from its stripped base spec — the base compile warms
the oracle store, the repair delta-recompiles from it — and byte-compared
against the cold compile of the transformed spec.  Each row records
``repair_time_s`` vs ``cold_compile_time_s``; any byte mismatch fails the
sweep.

``--fixed-k K`` sweeps the §2.4 fixed-tree-count variant over the zoo
(allgather family only — rooted kinds always use k = λ(root)); topologies
where the floor-scaled graph can't be compiled for that k are reported in
the document's ``skipped`` list rather than failing the sweep.

The swept topologies come from the declarative zoo registry
(`repro.topo.spec.zoo_specs()` — the `ZOO_SPECS` table keyed by BENCH row
name), and ``--topology SPEC`` adds arbitrary non-zoo fabrics using the
full spec grammar, transforms included, without any code edit:

    python -m repro.cache.sweep --topology "torus2d:6x6@fail(0-1)" \
        "dragonfly:g4,p3"

Such rows are named by their canonical spec string.  All compilation goes
through the `repro.api.Collectives` facade (cache-first when a cache dir
is given).

Runs topologies in parallel with `concurrent.futures` (each worker
compiles one topology's whole family); pass a cache dir to make repeated
sweeps (and any launch that follows) skip compilation.

    PYTHONPATH=src python -m repro.cache.sweep --out BENCH_schedules.json
    PYTHONPATH=src python -m repro.cache.sweep --smoke   # 3 topologies, <60s
"""
from __future__ import annotations

import argparse
import concurrent.futures
import json
import os
import sys
import time
from fractions import Fraction
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.api import Collectives
from repro.core import schedule as schedule_mod
from repro.core import simulate as sim
from repro.core.graph import DiGraph
from repro.topo.spec import TopologySpec, zoo_specs

from .fingerprint import compiler_fingerprint

BENCH_FORMAT = "repro.bench_schedules"
# v5: adds the optional ``repair`` section (--repair): per (topology,
# transform, kind) rows with ``repair_time_s`` vs ``cold_compile_time_s``
# and the byte-identity verdict of the repaired artifact.
# v6: normalizes ``compile_stats`` from a {stage: seconds} mapping to an
# aggregatable ``[{stage, seconds, probes, augments}]`` list in pipeline
# order (see cache README).
# v7: adds ``alltoall`` rows (swept at P = ALLTOALL_CHUNKS, lower bound =
# the exact bisection-cut `alltoall_lb`); repair rows for alltoall are
# always ``skipped`` (repair rejects the kind).
BENCH_VERSION = 7
SMOKE_NAMES = ("ring8", "hypercube3", "fig1a")
# the scaled-up zoo rows (64-compute fabrics where split/pack dominate);
# all of them are committed BENCH rows, and a full sweep document fed to
# tools/perf_smoke.py --measured gates every one of them
LARGE_NAMES = ("torus8x8", "torus8x8_failed", "fattree8p4l2h",
               "fattree8p4l2h_degraded", "fattree8p4l4h", "dragonfly6x4",
               "dragonfly6x4_degraded", "torus16x16")
# what the perf gate compiles fresh by default: the smoke rows plus two
# scaled-up fabrics — dragonfly6x4 (cheapest 64-compute row) and
# fattree8p4l2h (the §2.3 pack hot-path poster child, cheap since the
# fast-substrate packer landed; tools/perf_smoke.py gates its pack stage
# individually)
PERF_GATE_NAMES = SMOKE_NAMES + ("dragonfly6x4", "fattree8p4l2h")
COLLECTIVES = ("allgather", "reduce_scatter", "broadcast", "reduce",
               "allreduce", "alltoall")
# kinds a --fixed-k sweep exercises (rooted kinds always use k = λ(root))
FIXED_K_COLLECTIVES = ("allgather", "reduce_scatter", "allreduce",
                       "alltoall")
# alltoall sweeps at P = 1: each spanning tree already pipelines N−1
# distinct destination blocks back-to-back, so its rounds stay full
# without sub-chunking and the P >= depth acceptance rule does not apply
ALLTOALL_CHUNKS = 1


def default_out_path(partial: bool) -> str:
    """Partial runs (--smoke / explicit --names) write a scratch file so the
    committed full-sweep scoreboard is never clobbered."""
    return "BENCH_schedules.smoke.json" if partial else "BENCH_schedules.json"


def claim_mismatches(doc: Dict[str, Any]) -> List[str]:
    """Entries whose re-simulated runtime != the claimed runtime."""
    return [f"{e['name']}:{e.get('kind', 'allgather')}"
            for e in doc["entries"] if e["achieved_over_claimed"] != "1"]


def sweep_registry() -> Dict[str, Callable[[], DiGraph]]:
    """The expanded zoo (paper families + hypercube/BCube/mesh-of-DGX and
    degraded / failed-link variants) as ``{row_name: builder}``, derived
    from the declarative `repro.topo.zoo.ZOO_SPECS` registry."""
    return {name: spec.build for name, spec in zoo_specs().items()}


def _build_topology(name: str) -> DiGraph:
    """A sweep row's graph: a committed zoo row name, or (for --topology
    rows) the canonical spec string itself."""
    specs = zoo_specs()
    if name in specs:
        return specs[name].build()
    return TopologySpec.parse(name).build()


def _known_name(name: str) -> bool:
    if name in zoo_specs():
        return True
    try:
        TopologySpec.parse(name)
        return True
    except ValueError:
        return False


def _compile(kind: str, g: DiGraph, num_chunks: int,
             cache_dir: Optional[str], root: Optional[int],
             fixed_k: Optional[int] = None):
    return Collectives(cache=cache_dir).schedule(
        g, kind=kind, root=root, num_chunks=num_chunks,
        fixed_k=None if kind in ("broadcast", "reduce") else fixed_k)


def _compile_family(g: DiGraph, kinds: Sequence[str], num_chunks: int,
                    cache_dir: Optional[str], root: Optional[int],
                    fixed_k: Optional[int], timings: Dict[str, float],
                    packed: Dict[str, Any],
                    pack_jobs: int = 1) -> Dict[str, Any]:
    """One topology's whole collective family, stages shared across kinds
    (cache-backed when a cache dir is given); `timings` receives per-kind
    marginal wall seconds, `packed` the pre-rounds plans (fresh-compile
    path only — a cache hit needs no re-rounding plan); ``pack_jobs > 1``
    packs the independent orientations in worker processes."""
    return Collectives(cache=cache_dir).family(
        g, kinds, num_chunks=num_chunks, fixed_k=fixed_k, root=root,
        timings=timings, packed_out=packed, jobs=pack_jobs)


def _rechunked(packed_plan, num_chunks: int):
    """Rounds + emit of a packed plan at a larger chunk count (stages 1-3
    are P-independent, so the packed products are reused as-is)."""
    import dataclasses
    from repro.core import plan as plan_mod
    return plan_mod.emit(plan_mod.rounds(
        dataclasses.replace(packed_plan, num_chunks=num_chunks)))


_SIMULATORS = {
    "allgather": sim.simulate_allgather,
    "reduce_scatter": sim.simulate_reduce_scatter,
    "broadcast": sim.simulate_broadcast,
    "reduce": sim.simulate_reduce,
    "allreduce": sim.simulate_allreduce,
    "alltoall": sim.simulate_alltoall,
}


def _depth(sched) -> int:
    if isinstance(sched, schedule_mod.AllReduceSchedule):
        return max(sched.rs.depth, sched.ag.depth)
    return sched.depth


def _compile_stats(sched) -> Optional[List[Dict[str, Any]]]:
    """An artifact's per-stage compiler record, normalized (BENCH v6) to an
    aggregatable ``[{stage, seconds, probes, augments}]`` list in pipeline
    order — allreduce sums its two halves stage-by-stage.  None when the
    artifact carries no instrumentation."""
    halves = (sched.rs, sched.ag) \
        if isinstance(sched, schedule_mod.AllReduceSchedule) else (sched,)
    order: List[str] = []
    acc: Dict[str, Dict[str, Any]] = {}
    for half in halves:
        cs = half.compile_stats
        if cs is None:
            continue
        for s in cs.stages:
            row = acc.get(s.stage)
            if row is None:
                order.append(s.stage)
                row = acc[s.stage] = {"stage": s.stage, "seconds": 0.0,
                                      "probes": 0, "augments": 0}
            row["seconds"] = round(row["seconds"] + s.wall_time_s, 6)
            row["probes"] += int(s.meta.get("probes", 0))
            row["augments"] += int(s.meta.get("augments", 0))
    return [acc[stage] for stage in order] or None


def _oracle_counters(sched) -> Dict[str, int]:
    """Summed maxflow probe/augment counters over the stages that produced
    the artifact (allreduce sums its halves; zero for uninstrumented
    artifacts)."""
    halves = (sched.rs, sched.ag) \
        if isinstance(sched, schedule_mod.AllReduceSchedule) else (sched,)
    probes = augments = 0
    for half in halves:
        cs = half.compile_stats
        if cs is None:
            continue
        for stage in cs.stages:
            probes += stage.meta.get("probes", 0)
            augments += stage.meta.get("augments", 0)
    return {"oracle_probes": probes, "oracle_augments": augments}


def _entry(name: str, kind: str, g: DiGraph, root: Optional[int],
           fixed_k: Optional[int], sched,
           compile_time: float) -> Dict[str, Any]:
    """Verify one compiled artifact chunk-by-chunk, simulate, and build its
    scoreboard row."""
    rep = _SIMULATORS[kind](sched, verify=True)   # replays every chunk
    achieved = rep.sim_time
    # Cache path: `claimed` was recorded in the artifact at compile time, so
    # achieved == claimed is a real replay-fidelity check.  Fresh-compile
    # path: adopt the verified run as the claim (simulating twice in one
    # process would only compare the simulator against itself).
    claimed = sched.claimed_runtime
    if claimed is None:
        claimed = achieved
    lb = rep.lb_time
    if isinstance(sched, schedule_mod.AllReduceSchedule):
        opt, num_p = sched.rs.opt, sched.rs.num_chunks
        rounds = len(sched.rs.rounds) + len(sched.ag.rounds)
        sends = sched.rs.total_sends() + sched.ag.total_sends()
    else:
        opt, num_p = sched.opt, sched.num_chunks
        rounds, sends = len(sched.rounds), sched.total_sends()
    return {
        "name": name,
        "kind": kind,
        "root": root,
        "fixed_k": fixed_k,
        "topology": g.name,
        "fingerprint": g.fingerprint(),
        "num_nodes": g.num_nodes,
        "num_compute": g.num_compute,
        "num_switches": len(g.switches),
        "num_edges": len(g.cap),
        "num_chunks": num_p,
        "compile_time_s": round(compile_time, 6),
        "compile_stats": _compile_stats(sched),
        **_oracle_counters(sched),
        "inv_x_star": str(opt.inv_x_star),
        "U": str(opt.U),
        "k": opt.k,
        "depth": _depth(sched),
        "rounds": rounds,
        "total_sends": sends,
        "lb_runtime": str(lb),
        "claimed_runtime": str(claimed),
        "achieved_runtime": str(achieved),
        "achieved_over_claimed": str(achieved / claimed),
        "achieved_over_lb": str(achieved / lb),
        "achieved_over_lb_float": float(achieved / lb),
        "verified": True,
    }


def sweep_one(name: str, kind: str = "allgather", num_chunks: int = 16,
              cache_dir: Optional[str] = None,
              fixed_k: Optional[int] = None) -> Dict[str, Any]:
    """Compile one (topology, collective) pair (P >= depth enforced; alltoall
    sweeps at P = ALLTOALL_CHUNKS, exempt from the rule), verify
    chunk-by-chunk, simulate, and return a scoreboard entry."""
    g = _build_topology(name)
    root = min(g.compute) if kind in ("broadcast", "reduce") else None
    if kind == "alltoall":
        num_chunks = ALLTOALL_CHUNKS
    t0 = time.perf_counter()
    sched = _compile(kind, g, num_chunks, cache_dir, root, fixed_k)
    if kind != "alltoall" and _depth(sched) > num_chunks:
        # acceptance requires P >= tree depth
        sched = _compile(kind, g, _depth(sched), cache_dir, root, fixed_k)
    compile_time = time.perf_counter() - t0
    return _entry(name, kind, g, root, fixed_k, sched, compile_time)


def _alltoall_artifact(g: DiGraph, cache_dir: Optional[str],
                       fixed_k: Optional[int], packed: Dict[str, Any]):
    """One alltoall sweep artifact at P = ALLTOALL_CHUNKS.  On the
    fresh-compile path the allgather family's packed plan is re-tagged and
    only rounds + emit run (stages 1-3 are kind-independent — identical
    bytes to a cold `compile_alltoall`); the cache path (no packed plans)
    goes through the facade, which replays or compiles as usual."""
    if "allgather" in packed:
        import dataclasses
        from repro.core import plan as plan_mod
        src = packed["allgather"]
        p = dataclasses.replace(
            src, kind="alltoall", num_chunks=ALLTOALL_CHUNKS,
            stats=dataclasses.replace(src.stats.copy(), kind="alltoall"))
        return plan_mod.emit(plan_mod.rounds(p))
    return Collectives(cache=cache_dir).schedule(
        g, kind="alltoall", num_chunks=ALLTOALL_CHUNKS, fixed_k=fixed_k)


def _sweep_topology(name: str, kinds: Sequence[str], num_chunks: int,
                    cache_dir: Optional[str], fixed_k: Optional[int],
                    pack_jobs: int = 1) -> List[Dict[str, Any]]:
    """All of one topology's sweep rows, compiled as a single family so
    solve/split/pack are amortized across the collective kinds; each row's
    ``compile_time_s`` is its kind's marginal wall time.  Alltoall is
    carved out of the family call (it sweeps at P = ALLTOALL_CHUNKS, not
    the sweep's chunk count) and built from the family's packed allgather
    plan — see `_alltoall_artifact`.

    Under --fixed-k, topologies that can't compile for the requested k
    (e.g. the floor-scaled graph loses the Eulerian condition) fall back to
    per-kind compilation so any kind that *can* compile still gets a row,
    and the infeasible kinds become `skipped` records instead of killing
    the sweep.  Only the known infeasibility errors are tolerated — a
    PackingError or a verification failure is a compiler bug and still
    fails the run."""
    from repro.core.edge_split import EdgeSplitError
    g = _build_topology(name)
    root = (min(g.compute)
            if any(k in ("broadcast", "reduce") for k in kinds) else None)
    fam_kinds = [k for k in kinds if k != "alltoall"]
    try:
        timings: Dict[str, float] = {}
        packed: Dict[str, Any] = {}
        arts: Dict[str, Any] = {}
        if fam_kinds:
            arts = _compile_family(g, fam_kinds, num_chunks, cache_dir, root,
                                   fixed_k, timings, packed, pack_jobs)
        if "alltoall" in kinds:
            t0 = time.perf_counter()
            arts["alltoall"] = _alltoall_artifact(g, cache_dir, fixed_k,
                                                  packed)
            timings["alltoall"] = time.perf_counter() - t0
    except (EdgeSplitError, ValueError) as e:
        if fixed_k is None:
            raise
        results = []
        for kind in kinds:
            try:
                results.append(sweep_one(name, kind, num_chunks, cache_dir,
                                         fixed_k))
            except (EdgeSplitError, ValueError) as e:
                results.append({"name": name, "kind": kind,
                                "fixed_k": fixed_k,
                                "skipped": f"{type(e).__name__}: {e}"})
        return results
    rows = []
    for kind in kinds:
        sched = arts[kind]
        kind_root = root if kind in ("broadcast", "reduce") else None
        extra = 0.0
        if kind != "alltoall" and _depth(sched) > num_chunks:
            # acceptance requires P >= tree depth (alltoall exempt: its
            # destination blocks fill the pipeline at P = 1)
            t0 = time.perf_counter()
            need = _depth(sched)
            if kind == "allreduce" and "reduce_scatter" in packed:
                sched = schedule_mod.AllReduceSchedule(
                    rs=_rechunked(packed["reduce_scatter"], need),
                    ag=_rechunked(packed["allgather"], need))
            elif kind in packed:
                sched = _rechunked(packed[kind], need)
            else:   # cache path: re-ask the cache at the larger P
                sched = _compile(kind, g, need, cache_dir, kind_root,
                                 None if kind_root is not None else fixed_k)
            extra = time.perf_counter() - t0
        rows.append(_entry(name, kind, g, kind_root, fixed_k, sched,
                           timings.get(kind, 0.0) + extra))
    return rows


def _repair_target(name: str):
    """(base_spec, transform) of a transformed sweep row, or None for rows
    without a (single) transform."""
    import dataclasses
    spec = zoo_specs().get(name)
    if spec is None:
        try:
            spec = TopologySpec.parse(name)
        except ValueError:
            return None
    if len(spec.transforms) != 1:
        return None
    return dataclasses.replace(spec, transforms=()), spec.transforms[0]


def _repair_topology(name: str, kinds: Sequence[str],
                     num_chunks: int) -> List[Dict[str, Any]]:
    """BENCH v5 repair rows for one transformed zoo row: compile the
    stripped base spec (warming the in-process oracle store), cold-compile
    the transformed spec, then `repair_artifact` from the base — asserting
    the repaired schedule is byte-identical to the cold compile and
    recording ``repair_time_s`` vs ``cold_compile_time_s``."""
    from repro.core.repair import RepairError, repair_artifact
    from .serialize import allreduce_to_json, schedule_to_json
    target = _repair_target(name)
    if target is None:
        return []
    base_spec, transform = target
    base_g = base_spec.build()
    deg_g = _build_topology(name)
    coll = Collectives(cache=None)
    rows: List[Dict[str, Any]] = []
    for kind in kinds:
        if kind == "alltoall":
            # repair rejects the kind outright — record the skip without
            # paying for the base + cold compiles it would take to find out
            rows.append({"name": name, "kind": kind,
                         "transform": str(transform),
                         "base_topology": base_g.name,
                         "skipped": "RepairError: repair does not support "
                                    "alltoall artifacts"})
            continue
        root = min(base_g.compute) if kind in ("broadcast", "reduce") \
            else None
        base_art = coll.schedule(base_g, kind=kind, root=root,
                                 num_chunks=num_chunks)
        t0 = time.perf_counter()
        cold_art = coll.schedule(deg_g, kind=kind, root=root,
                                 num_chunks=num_chunks)
        cold_s = time.perf_counter() - t0
        row: Dict[str, Any] = {
            "name": name, "kind": kind, "transform": str(transform),
            "base_topology": base_g.name,
            "cold_compile_time_s": round(cold_s, 6),
        }
        try:
            rep_art, report = repair_artifact(base_art, transform,
                                              verify=True)
        except RepairError as e:
            row["skipped"] = f"RepairError: {e}"
            rows.append(row)
            continue
        to_json = allreduce_to_json if kind == "allreduce" \
            else schedule_to_json
        row.update({
            "repair_time_s": round(report.repair_time_s, 6),
            "speedup": round(cold_s / report.repair_time_s, 4)
            if report.repair_time_s > 0 else None,
            "warm_solve": report.warm_solve,
            "warm_split": report.warm_split,
            "solve_rounds": report.solve_rounds,
            "bytes_equal": to_json(rep_art) == to_json(cold_art),
        })
        rows.append(row)
    return rows


def repair_mismatches(doc: Dict[str, Any]) -> List[str]:
    """Repair rows whose repaired artifact is not byte-identical to the
    cold compile of the transformed spec."""
    return [f"{e['name']}:{e['kind']}" for e in doc.get("repair", ())
            if "skipped" not in e and not e.get("bytes_equal")]


def run_sweep(names: Optional[Sequence[str]] = None, num_chunks: int = 16,
              jobs: Optional[int] = None, cache_dir: Optional[str] = None,
              out_path: Optional[str] = None,
              collectives: Optional[Sequence[str]] = None,
              fixed_k: Optional[int] = None,
              topologies: Optional[Sequence[str]] = None,
              repair: bool = False, pack_jobs: int = 1) -> Dict[str, Any]:
    """Sweep the named zoo rows (default: all of them) plus any extra
    `topologies` given as raw spec strings (rows named by the canonical
    spec form); `names` entries may themselves be spec strings.

    ``repair=True`` adds the BENCH v5 ``repair`` section: every swept row
    with a transform is re-derived by online repair from its stripped base
    spec and byte-compared against the cold compile (see
    `_repair_topology`).

    ``pack_jobs > 1`` packs each family's independent orientations/kinds
    in worker processes (artifacts byte-identical to sequential); it only
    engages when topology-level `jobs` parallelism is not already
    saturating the machine."""
    names = list(names) if names is not None else (
        [] if topologies else list(sweep_registry()))
    for text in topologies or ():
        names.append(str(TopologySpec.parse(text)))
    unknown = [n for n in names if not _known_name(n)]
    if unknown:
        raise KeyError(f"unknown sweep topologies: {unknown}")
    if collectives is None:
        collectives = list(FIXED_K_COLLECTIVES if fixed_k is not None
                           else COLLECTIVES)
    else:
        collectives = list(collectives)
    bad_kinds = [c for c in collectives if c not in COLLECTIVES]
    if bad_kinds:
        raise KeyError(f"unknown collectives: {bad_kinds}")
    if fixed_k is not None:
        rooted = [c for c in collectives if c not in FIXED_K_COLLECTIVES]
        if rooted:
            raise KeyError(f"--fixed-k does not apply to rooted kinds "
                           f"{rooted} (k = λ(root) there)")
        if repair:
            raise KeyError("--repair measures the automatic-k compiler "
                           "(fixed-k artifacts don't delta-compose); "
                           "drop --fixed-k")
    jobs = jobs if jobs is not None else min(len(names),
                                             max(1, (os.cpu_count() or 2)))
    if jobs <= 1 or len(names) <= 1:
        grouped = [_sweep_topology(n, collectives, num_chunks, cache_dir,
                                   fixed_k, pack_jobs) for n in names]
    else:
        # topology-level processes already saturate the pool; nesting the
        # per-family pack pool under them would oversubscribe
        with concurrent.futures.ProcessPoolExecutor(max_workers=jobs) as ex:
            futs = {ex.submit(_sweep_topology, n, collectives, num_chunks,
                              cache_dir, fixed_k, 1): n
                    for n in names}
            grouped = [f.result() for f in futs]
    results = [e for rows in grouped for e in rows]
    entries = [e for e in results if "skipped" not in e]
    skipped = [e for e in results if "skipped" in e]
    order = lambda e: (e["name"], COLLECTIVES.index(e["kind"]))  # noqa: E731
    entries.sort(key=order)
    skipped.sort(key=order)
    repair_rows: List[Dict[str, Any]] = []
    if repair:
        # fixed-k artifacts don't delta-compose (the floor isn't recorded),
        # so the repair section always measures the automatic-k compiler
        repair_kinds = [c for c in collectives]
        targets = [n for n in names if _repair_target(n) is not None]
        if jobs <= 1 or len(targets) <= 1:
            rep_grouped = [_repair_topology(n, repair_kinds, num_chunks)
                           for n in targets]
        else:
            with concurrent.futures.ProcessPoolExecutor(
                    max_workers=jobs) as ex:
                futs = [ex.submit(_repair_topology, n, repair_kinds,
                                  num_chunks) for n in targets]
                rep_grouped = [f.result() for f in futs]
        repair_rows = sorted((e for rows in rep_grouped for e in rows),
                             key=order)
    doc = {
        "format": BENCH_FORMAT,
        "version": BENCH_VERSION,
        "compiler": compiler_fingerprint(),
        "num_chunks": num_chunks,
        "collectives": collectives,
        "fixed_k": fixed_k,
        "num_topologies": len(names),
        "num_entries": len(entries),
        "entries": entries,
        "skipped": skipped,
    }
    if repair:
        doc["repair"] = repair_rows
    if out_path:
        with open(out_path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
    return doc


def build_parser() -> argparse.ArgumentParser:
    """The sweep CLI (exposed separately so tools/check_docs.py can assert
    the documented flags match)."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help=f"only the 3 small smoke topologies {SMOKE_NAMES}")
    ap.add_argument("--names", nargs="*", default=None)
    ap.add_argument("--topology", nargs="*", default=None, metavar="SPEC",
                    help="extra topologies as TopologySpec strings (full "
                         "grammar incl. transforms, e.g. "
                         "'torus2d:6x6@fail(0-1)'); swept alongside --names "
                         "(or alone), rows named by the canonical spec form")
    ap.add_argument("--collectives", nargs="*", default=None,
                    choices=list(COLLECTIVES),
                    help="collective kinds to sweep (default: all of "
                         f"{COLLECTIVES})")
    ap.add_argument("--chunks", type=int, default=16)
    ap.add_argument("--fixed-k", type=int, default=None,
                    help="sweep the §2.4 fixed-tree-count variant "
                         f"(solve_fixed_k) with this k over {FIXED_K_COLLECTIVES}; "
                         "incompatible topologies land in the doc's "
                         "'skipped' list")
    ap.add_argument("--repair", action="store_true",
                    help="add the BENCH v5 repair section: every swept row "
                         "with a transform is also produced by online "
                         "repair from its stripped base spec "
                         "(repro.core.repair), byte-compared against the "
                         "cold compile, and timed (repair_time_s vs "
                         "cold_compile_time_s)")
    ap.add_argument("--jobs", type=int, default=None)
    ap.add_argument("--pack-jobs", type=int, default=1,
                    help="worker processes for the per-family split/pack "
                         "stages (pays on single-topology sweeps; ignored "
                         "when topology-level --jobs parallelism is active)")
    ap.add_argument("--cache-dir", default=None)
    ap.add_argument("--out", default=None,
                    help="output path (default BENCH_schedules.json; a "
                         "partial run — --smoke/--names — defaults to "
                         "BENCH_schedules.smoke.json so the committed "
                         "full-sweep scoreboard is never clobbered)")
    return ap


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    names = list(SMOKE_NAMES) if args.smoke else args.names
    if args.out is None:
        args.out = default_out_path(
            partial=names is not None or args.topology is not None
            or args.fixed_k is not None)
    doc = run_sweep(names=names, num_chunks=args.chunks, jobs=args.jobs,
                    cache_dir=args.cache_dir, out_path=args.out,
                    collectives=args.collectives, fixed_k=args.fixed_k,
                    topologies=args.topology, repair=args.repair,
                    pack_jobs=args.pack_jobs)
    for e in doc["entries"]:
        print(f"{e['name']}.{e['kind']},{e['compile_time_s'] * 1e6:.1f},"
              f"inv_x*={e['inv_x_star']};k={e['k']};depth={e['depth']};"
              f"claimed={e['claimed_runtime']};"
              f"achieved/claimed={e['achieved_over_claimed']};"
              f"achieved/lb={e['achieved_over_lb_float']:.4f}", flush=True)
    for e in doc["skipped"]:
        print(f"{e['name']}.{e['kind']},skipped,{e['skipped']}", flush=True)
    for e in doc.get("repair", ()):
        if "skipped" in e:
            print(f"repair {e['name']}.{e['kind']},skipped,{e['skipped']}",
                  flush=True)
        else:
            print(f"repair {e['name']}.{e['kind']} {e['transform']}: "
                  f"repair={e['repair_time_s'] * 1e3:.1f}ms "
                  f"cold={e['cold_compile_time_s'] * 1e3:.1f}ms "
                  f"speedup={e['speedup']}x "
                  f"warm=(solve={e['warm_solve']},split={e['warm_split']}) "
                  f"bytes_equal={e['bytes_equal']}", flush=True)
    bad = claim_mismatches(doc)
    if bad:
        print(f"FAIL: achieved != claimed for {bad}", file=sys.stderr)
        return 1
    bad_repair = repair_mismatches(doc)
    if bad_repair:
        print(f"FAIL: repaired bytes != cold compile for {bad_repair}",
              file=sys.stderr)
        return 1
    print(f"wrote {args.out}: {doc['num_topologies']} topologies x "
          f"{len(doc['collectives'])} collectives = {doc['num_entries']} "
          f"entries ({len(doc['skipped'])} skipped), "
          f"compiler {doc['compiler']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
