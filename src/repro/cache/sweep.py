"""Topology-zoo sweep: compile + simulate + verify every topology, emit
`BENCH_schedules.json` — the repo's schedule-quality scoreboard.

Every entry records compile time, the exact optimal bound 1/x*, the
schedule's claimed pipelined runtime, the re-simulated achieved runtime and
their exact ratio (``achieved_over_claimed`` must be "1": the verifier
replays every chunk, so a schedule that does not reproduce its claim fails
the sweep).  ``achieved_over_lb`` tracks convergence to the asymptotic
bound as the chunk count grows.

Runs topologies in parallel with `concurrent.futures`; pass a cache dir to
make repeated sweeps (and any launch that follows) skip compilation.

    PYTHONPATH=src python -m repro.cache.sweep --out BENCH_schedules.json
    PYTHONPATH=src python -m repro.cache.sweep --smoke   # 3 topologies, <60s
"""
from __future__ import annotations

import argparse
import concurrent.futures
import json
import os
import sys
import time
from fractions import Fraction
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core import schedule as schedule_mod
from repro.core import simulate as sim
from repro.core.graph import DiGraph
from repro.topo import (bcube, bidir_ring, degrade_link, dgx_box, dragonfly,
                        fail_link, fat_tree, fig1a, hypercube, line,
                        mesh_of_dgx, multipod_topology, ring, star_switch,
                        torus_2d, two_cluster_switch)

from .fingerprint import compiler_fingerprint

BENCH_FORMAT = "repro.bench_schedules"
SMOKE_NAMES = ("ring8", "hypercube3", "fig1a")


def default_out_path(partial: bool) -> str:
    """Partial runs (--smoke / explicit --names) write a scratch file so the
    committed full-sweep scoreboard is never clobbered."""
    return "BENCH_schedules.smoke.json" if partial else "BENCH_schedules.json"


def claim_mismatches(doc: Dict[str, Any]) -> List[str]:
    """Names of entries whose re-simulated runtime != the claimed runtime."""
    return [e["name"] for e in doc["entries"]
            if e["achieved_over_claimed"] != "1"]


def sweep_registry() -> Dict[str, Callable[[], DiGraph]]:
    """The expanded zoo: paper families + hypercube/BCube/mesh-of-DGX and
    degraded / failed-link variants."""
    return {
        "fig1a": fig1a,
        "fig1a_degraded": lambda: degrade_link(
            two_cluster_switch(4, 10, 2), 0, 8, 1, name="fig1a-deg"),
        "ring8": lambda: ring(8),
        "bring8": lambda: bidir_ring(8),
        "bring8_degraded": lambda: degrade_link(bidir_ring(8, cap=2), 0, 1, 1),
        "line6": lambda: line(6),
        "torus4x4": lambda: torus_2d(4, 4),
        "torus3x3_failed": lambda: fail_link(torus_2d(3, 3), 0, 1),
        "hypercube3": lambda: hypercube(3),
        "hypercube3_failed": lambda: fail_link(hypercube(3), 0, 1),
        "bcube2": lambda: bcube(2),
        "bcube3": lambda: bcube(3),
        "meshdgx2x2": lambda: mesh_of_dgx(2, 2, 2),
        "meshdgx2x2_degraded": lambda: degrade_link(
            mesh_of_dgx(2, 2, 2, nvlink_cap=4, dcn_cap=2), 8, 9, 1),
        "fattree": fat_tree,
        "dragonfly": dragonfly,
        "dgx8": dgx_box,
        "star8": lambda: star_switch(8),
        "two_cluster_3x6": lambda: two_cluster_switch(3, 6, 2),
        "multipod": lambda: multipod_topology(2, 4, 10, 1),
    }


def sweep_one(name: str, num_chunks: int = 16,
              cache_dir: Optional[str] = None) -> Dict[str, Any]:
    """Compile (P >= depth enforced), verify chunk-by-chunk, simulate."""
    g = sweep_registry()[name]()

    def compiled(p: int):
        if cache_dir:
            from .store import ScheduleCache
            return ScheduleCache(cache_dir).allgather(g, num_chunks=p)
        return schedule_mod.compile_allgather(g, num_chunks=p)

    t0 = time.perf_counter()
    sched = compiled(num_chunks)
    if sched.depth > num_chunks:       # acceptance requires P >= tree depth
        sched = compiled(sched.depth)
    compile_time = time.perf_counter() - t0

    rep = sim.simulate_allgather(sched, verify=True)   # replays every chunk
    achieved = rep.sim_time
    # Cache path: `claimed` was recorded in the artifact at compile time, so
    # achieved == claimed is a real replay-fidelity check.  Fresh-compile
    # path: adopt the verified run as the claim (simulating twice in one
    # process would only compare the simulator against itself).
    if sched.claimed_runtime is None:
        sched.claimed_runtime = achieved
    claimed = sched.claimed_runtime
    lb = rep.lb_time
    return {
        "name": name,
        "topology": g.name,
        "fingerprint": g.fingerprint(),
        "num_nodes": g.num_nodes,
        "num_compute": g.num_compute,
        "num_switches": len(g.switches),
        "num_edges": len(g.cap),
        "num_chunks": sched.num_chunks,
        "compile_time_s": round(compile_time, 6),
        "inv_x_star": str(sched.opt.inv_x_star),
        "U": str(sched.opt.U),
        "k": sched.opt.k,
        "depth": sched.depth,
        "rounds": len(sched.rounds),
        "total_sends": sched.total_sends(),
        "lb_runtime": str(lb),
        "claimed_runtime": str(claimed),
        "achieved_runtime": str(achieved),
        "achieved_over_claimed": str(achieved / claimed),
        "achieved_over_lb": str(achieved / lb),
        "achieved_over_lb_float": float(achieved / lb),
        "verified": True,
    }


def run_sweep(names: Optional[Sequence[str]] = None, num_chunks: int = 16,
              jobs: Optional[int] = None, cache_dir: Optional[str] = None,
              out_path: Optional[str] = None) -> Dict[str, Any]:
    names = list(names if names is not None else sweep_registry())
    unknown = [n for n in names if n not in sweep_registry()]
    if unknown:
        raise KeyError(f"unknown sweep topologies: {unknown}")
    jobs = jobs if jobs is not None else min(len(names),
                                             max(1, (os.cpu_count() or 2)))
    if jobs <= 1 or len(names) <= 1:
        entries = [sweep_one(n, num_chunks, cache_dir) for n in names]
    else:
        with concurrent.futures.ProcessPoolExecutor(max_workers=jobs) as ex:
            futs = {ex.submit(sweep_one, n, num_chunks, cache_dir): n
                    for n in names}
            entries = [f.result() for f in futs]
    entries.sort(key=lambda e: e["name"])
    doc = {
        "format": BENCH_FORMAT,
        "version": 1,
        "compiler": compiler_fingerprint(),
        "num_chunks": num_chunks,
        "num_topologies": len(entries),
        "entries": entries,
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
    return doc


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help=f"only the 3 small smoke topologies {SMOKE_NAMES}")
    ap.add_argument("--names", nargs="*", default=None)
    ap.add_argument("--chunks", type=int, default=16)
    ap.add_argument("--jobs", type=int, default=None)
    ap.add_argument("--cache-dir", default=None)
    ap.add_argument("--out", default=None,
                    help="output path (default BENCH_schedules.json; a "
                         "partial run — --smoke/--names — defaults to "
                         "BENCH_schedules.smoke.json so the committed "
                         "full-sweep scoreboard is never clobbered)")
    args = ap.parse_args(argv)
    names = list(SMOKE_NAMES) if args.smoke else args.names
    if args.out is None:
        args.out = default_out_path(partial=names is not None)
    doc = run_sweep(names=names, num_chunks=args.chunks, jobs=args.jobs,
                    cache_dir=args.cache_dir, out_path=args.out)
    for e in doc["entries"]:
        print(f"{e['name']},{e['compile_time_s'] * 1e6:.1f},"
              f"inv_x*={e['inv_x_star']};k={e['k']};depth={e['depth']};"
              f"claimed={e['claimed_runtime']};"
              f"achieved/claimed={e['achieved_over_claimed']};"
              f"achieved/lb={e['achieved_over_lb_float']:.4f}", flush=True)
    bad = claim_mismatches(doc)
    if bad:
        print(f"FAIL: achieved != claimed for {bad}", file=sys.stderr)
        return 1
    print(f"wrote {args.out}: {doc['num_topologies']} topologies, "
          f"compiler {doc['compiler']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
