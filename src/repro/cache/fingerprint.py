"""Content-addressed keys for schedule artifacts.

Two fingerprints combine into a cache key:

* ``graph_fingerprint`` — the topology side.  Canonical form = node count +
  compute set + switch set + sorted edge/capacity multiset (see
  `DiGraph.canonical_form`); the display name is excluded, so structurally
  identical topologies share entries.

* ``compiler_fingerprint`` — the code side.  A hash over the *source text*
  of every `repro.core` module that participates in compilation plus the
  artifact `FORMAT_VERSION`.  Any edit to the optimality search, edge
  splitting, packing, round construction or the serialization schema
  changes the fingerprint and invalidates every cached schedule — stale
  artifacts are never replayed after a compiler change.
"""
from __future__ import annotations

import hashlib
from functools import lru_cache
from typing import Optional

from repro.core.graph import DiGraph

# Bump when the JSON schema in serialize.py changes incompatibly.
# v2: schedule payloads carry an explicit `root` field (single-root
# broadcast/reduce kinds; null for allgather/reduce-scatter), and the kind
# vocabulary grew to {allgather, reduce_scatter, broadcast, reduce}.
# v3: the kind vocabulary grew `alltoall` (per-source scatter-tree
# schedules whose slots fold the destination in: slot = dest·k·P +
# subslot); the field layout is unchanged, but older readers would
# mis-simulate an alltoall payload, so the version gates them out.
FORMAT_VERSION = 3

# Modules whose behaviour determines what a compiled schedule looks like.
_COMPILER_MODULES = (
    "repro.core.graph",
    "repro.core.maxflow",
    "repro.core.optimality",
    "repro.core.edge_split",
    "repro.core.arborescence",
    "repro.core.fixed_k",
    "repro.core.schedule",
    "repro.core.plan",
    "repro.core.repair",
    "repro.core.simulate",
)


def graph_fingerprint(g: DiGraph) -> str:
    return g.fingerprint()


@lru_cache(maxsize=1)
def compiler_fingerprint() -> str:
    """Hex digest (16 chars) of the schedule compiler's source code."""
    import importlib

    h = hashlib.sha256()
    h.update(f"format={FORMAT_VERSION}".encode())
    for name in _COMPILER_MODULES:
        mod = importlib.import_module(name)
        path = getattr(mod, "__file__", None)
        h.update(name.encode())
        if path:
            with open(path, "rb") as f:
                h.update(f.read())
    return h.hexdigest()[:16]


def schedule_cache_key(kind: str, topo: DiGraph, num_chunks: int,
                       fixed_k: Optional[int] = None,
                       root: Optional[int] = None,
                       compiler_fp: Optional[str] = None) -> str:
    """Filename-safe key identifying one compiled artifact."""
    parts = [kind, topo.fingerprint(), f"p{num_chunks}",
             f"k{fixed_k if fixed_k is not None else 'auto'}"]
    if root is not None:
        parts.append(f"r{root}")
    parts.append(compiler_fp or compiler_fingerprint())
    return "-".join(parts)


def transform_slug(transform) -> str:
    """Filename-safe token for a `TransformSpec` — ``@degrade(0-8,cap=1)``
    becomes ``degrade.0-8.cap=1`` — stable across processes because
    `TransformSpec.__str__` is canonical (sorted kwargs)."""
    import re

    return re.sub(r"[^A-Za-z0-9.=_-]+", ".", str(transform).lstrip("@")).strip(".")


def repair_cache_key(kind: str, base_topo: DiGraph, transform,
                     num_chunks: int, fixed_k: Optional[int] = None,
                     root: Optional[int] = None,
                     compiler_fp: Optional[str] = None) -> str:
    """Key for the `.repair` sidecar of one repaired artifact.

    Keyed by the *base* (pre-fault) graph fingerprint plus the transform —
    not by the degraded graph — so an online repair path can look up "base
    artifact X under fault Y" without first building the degraded topology.
    The sidecar then points at the repaired artifact, which lives under its
    natural degraded-topology `schedule_cache_key`.
    """
    parts = ["repair", kind, base_topo.fingerprint(), transform_slug(transform),
             f"p{num_chunks}", f"k{fixed_k if fixed_k is not None else 'auto'}"]
    if root is not None:
        parts.append(f"r{root}")
    parts.append(compiler_fp or compiler_fingerprint())
    return "-".join(parts)
