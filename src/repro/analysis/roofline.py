"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = Σ per-kind collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from `compiled.cost_analysis()` (per-device SPMD
module → multiply by chip count for totals; we keep per-device and divide by
per-chip peak, which is equivalent).  Collective bytes are NOT in
cost_analysis: we parse the HLO text and sum operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

The collective term charges bytes to the slowest link they traverse: ICI
(~50 GB/s/link) for intra-pod axes; DCN for the 'pod' axis (identified via
replica-group stride analysis when possible, else worst-cased as ICI).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.topo.tpu import TPU_V5E, HardwareSpec

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")

# e.g.  bf16[8,128,256]{2,1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+(?P<result>\([^=]*?\)|\S+)\s+"          # result shape (or tuple)
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)"
    r"(?P<async>-start|-done)?\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))           # [num_groups, group_size]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def _wire_bytes(kind: str, result_bytes: int, g: int) -> float:
    """Per-device link bytes for one collective, ring-algorithm model.
    `result_bytes` is the per-device RESULT buffer size from the SPMD HLO."""
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "all-gather":          # result = gathered (full) buffer
        return result_bytes * (g - 1) / g
    if kind == "reduce-scatter":      # result = scattered piece
        return result_bytes * (g - 1)
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)        # collective-permute: one hop


def _iter_collectives(hlo_text: str):
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or m.group("async") == "-done":
            continue  # -done pairs with -start; count once
        shapes = _SHAPE_RE.findall(m.group("result"))
        result_bytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        yield m.group("kind"), result_bytes, _group_size(line)


def collective_bytes_by_kind(hlo_text: str) -> Dict[str, int]:
    """Per-device wire bytes per collective kind, from the SPMD HLO."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    for kind, result_bytes, g in _iter_collectives(hlo_text):
        out[kind] += int(_wire_bytes(kind, result_bytes, g))
    return out


def collective_op_counts(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    for kind, _, _ in _iter_collectives(hlo_text):
        out[kind] += 1
    return out


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                # per-device
    hlo_bytes: float                # per-device HBM traffic
    collective_bytes: Dict[str, int]  # per-device, by kind
    model_flops: float              # 6·N·D (or 6·N_active·D) total
    hw: HardwareSpec = TPU_V5E
    ici_links_per_axis: int = 2     # bidirectional ring: 2 egress links/chip

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / self.hw.peak_flops_bf16

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / self.hw.hbm_bw

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))

    @property
    def collective_s(self) -> float:
        # per-device collective bytes over per-device ICI egress bandwidth
        bw = self.hw.ici_link_bw * self.ici_links_per_axis
        return self.total_collective_bytes / bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): compiled-compute efficiency —
        catches remat recompute and masked-attention waste."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute_s / max(all terms): 1.0 = perfectly compute-bound."""
        return self.compute_s / self.bound_s if self.bound_s else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_per_dev": self.hlo_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collective_bytes": dict(self.collective_bytes),
        }


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for training (D = tokens per step); 2·N·D for a
    forward-only step (prefill); decode: 2·N_active per token × batch."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
