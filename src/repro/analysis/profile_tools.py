"""Per-op cost attribution for the perf loop: where do the bytes/collective
bytes of a compiled cell actually go?"""
from __future__ import annotations

import re
from typing import List, Tuple

from . import hlo_count as hc


def top_contributors(hlo: str, n: int = 12, kind_filter=None
                     ) -> List[Tuple[float, float, str, str]]:
    """[(bytes, mult, kind, line)] sorted desc, trip-adjusted."""
    comps, entry = hc.parse_hlo(hlo)
    out = []

    def visit(name, mult=1.0):
        comp = comps.get(name)
        if comp is None:
            return
        for op in comp.ops:
            if op.kind == "while":
                refs = dict(re.findall(
                    r"(condition|body)=%([\w\.\-]+)", op.line))
                cond = comps.get(refs.get("condition", ""))
                visit(refs.get("body", ""),
                      mult * (hc._trip_count(cond) if cond else 1))
                continue
            kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if kind in hc._COLLECTIVE_KINDS and not op.kind.endswith("-done"):
                _, rb = hc._shape_elems_bytes(op.result)
                wb = hc._collective_wire_bytes(
                    kind, rb, hc._group_size(op.line))
                out.append((wb * mult, mult, "COLL:" + kind, op.line[:130]))
            if op.kind in ("constant", "parameter", "get-tuple-element",
                           "tuple", "bitcast", "while", "conditional",
                           "copy-start", "copy-done"):
                continue
            _, ob = hc._shape_elems_bytes(op.result)
            if op.kind in ("dynamic-slice", "slice", "gather"):
                out.append((2 * ob * mult, mult, op.kind, op.line[:130]))
                continue
            if op.kind in ("dynamic-update-slice", "scatter"):
                args = hc._ARGS_RE.findall(op.line.split("(", 1)[1])
                upd = 0
                if len(args) >= 2:
                    t = comp.shapes.get(args[1])
                    if t:
                        _, upd = hc._shape_elems_bytes(t)
                out.append((2 * upd * mult, mult, op.kind, op.line[:130]))
                continue
            tot = ob
            for a in hc._ARGS_RE.findall(op.line.split("(", 1)[1]):
                t = comp.shapes.get(a)
                if t:
                    tot += hc._shape_elems_bytes(t)[1]
            out.append((tot * mult, mult, op.kind, op.line[:130]))

    visit(entry)
    if kind_filter:
        out = [o for o in out if kind_filter in o[2]]
    out.sort(reverse=True)
    return out[:n]
