"""Exact FLOP/byte counting from optimized HLO text, with while-loop trip
multipliers.

XLA's `compiled.cost_analysis()` counts each while-loop BODY once, so any
`lax.scan` (our layer stacks, CE-loss chunks, microbatching) is undercounted
by its trip count.  This module re-derives:

* flops — every `dot` (2 × prod(output) × contracted size), recursing into
  fusions / calls / while bodies, multiplying while bodies by their trip
  count (parsed from the loop-condition computation's comparison constant).
* bytes — per top-level op (= one kernel): operands + outputs, with the
  same multipliers.  This is an upper-estimate of HBM traffic (XLA may keep
  some buffers in registers/cache); it is consistent across variants, which
  is what the perf loop needs.

Validated against unrolled-vs-scanned matmul stacks (tests/test_analysis.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def _split_header(stripped: str):
    """'%name (a: T, b: (U, V)) -> R {' -> (name, [(a, T), (b, '(U, V)')])
    with balanced-paren awareness; None if not a computation header."""
    m = _COMP_NAME_RE.match(stripped)
    if not m or not stripped.endswith("{") or "->" not in stripped:
        return None
    start = stripped.index("(", m.start(1))
    depth = 0
    end = -1
    for i in range(start, len(stripped)):
        if stripped[i] == "(":
            depth += 1
        elif stripped[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    if end < 0 or "->" not in stripped[end:]:
        return None
    inner = stripped[start + 1:end]
    params = []
    depth = 0
    tok = ""
    for ch in inner + ",":
        if ch == "," and depth == 0:
            if ":" in tok:
                pname, ptype = tok.split(":", 1)
                params.append((pname.strip().lstrip("%"), ptype.strip()))
            tok = ""
            continue
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        tok += ch
    return m.group(1), params
# result type may be a tuple containing /*index=N*/ comments; tuples never
# nest parens in HLO text, so [^()]* is safe
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^()]*\)|\S+))\s+([\w\-]+)\(")
_ATTR_COMP_RE = re.compile(r"(?:calls|condition|body|to_apply)=%([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_ARGS_RE = re.compile(r"%([\w\.\-]+)")


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    """Total (elements, bytes) over all shapes in `text` (handles tuples)."""
    elems = 0
    bytes_ = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


def _shape_dims(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class Op:
    name: str
    result: str               # result type text
    kind: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]    # param name -> type text
    ops: List[Op]
    shapes: Dict[str, str]    # op/param name -> result type text
    max_const: int = 1        # largest integer constant (trip-count probe)


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            hdr = _split_header(stripped)
            if hdr is not None:
                cur = Computation(hdr[0], {}, [], {})
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                for pname, ptype in hdr[1]:
                    cur.params[pname] = ptype
                    cur.shapes[pname] = ptype
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(stripped)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), stripped)
            cur.ops.append(op)
            cur.shapes[op.name] = op.result
            if op.kind == "constant":
                c = _CONST_RE.search(stripped)
                if c:
                    cur.max_const = max(cur.max_const, int(c.group(1)))
    return comps, entry


def _dot_flops(op: Op, comp: Computation) -> float:
    out = _shape_dims(op.result)
    if out is None:
        return 0.0
    _, out_dims = out
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    # contracted size from the lhs operand's shape
    args = _ARGS_RE.findall(op.line.split("(", 1)[1])
    contract = 1
    cm = _CONTRACT_RE.search(op.line)
    if args and cm is not None:
        lhs_type = comp.shapes.get(args[0])
        if lhs_type:
            sd = _shape_dims(lhs_type)
            if sd:
                dims = sd[1]
                for idx in (cm.group(1).split(",") if cm.group(1) else []):
                    i = int(idx)
                    if i < len(dims):
                        contract *= dims[i]
    return 2.0 * out_elems * contract


def _trip_count(cond: Computation) -> int:
    return max(cond.max_const, 1)


_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))           # [num_groups, group_size]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def _collective_wire_bytes(kind: str, result_bytes: float, g: int) -> float:
    """Per-device link bytes, ring-algorithm model, from the per-device
    SPMD result buffer size."""
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "all-gather":          # result = gathered (full) buffer
        return result_bytes * (g - 1) / g
    if kind == "reduce-scatter":      # result = scattered piece
        return result_bytes * (g - 1)
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)        # collective-permute: one hop


class _Cost:
    __slots__ = ("flops", "bytes", "coll", "coll_ops")

    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0
        self.coll = {k: 0.0 for k in _COLLECTIVE_KINDS}
        self.coll_ops = {k: 0 for k in _COLLECTIVE_KINDS}

    def add(self, other: "_Cost", mult: float = 1.0,
            with_bytes: bool = True) -> None:
        self.flops += mult * other.flops
        if with_bytes:
            self.bytes += mult * other.bytes
        for k in _COLLECTIVE_KINDS:
            self.coll[k] += mult * other.coll[k]
            self.coll_ops[k] += int(mult * other.coll_ops[k])


def count(text_or_comps, entry_name: Optional[str] = None
          ) -> Dict[str, object]:
    """Trip-adjusted {'flops','bytes','collective_bytes','collective_ops'}
    for the entry computation."""
    if isinstance(text_or_comps, str):
        comps, entry = parse_hlo(text_or_comps)
    else:
        comps, entry = text_or_comps
    entry = entry_name or entry
    memo: Dict[str, _Cost] = {}

    def visit(name: str) -> _Cost:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None:
            return _Cost()
        memo[name] = _Cost()             # cycle guard
        cost = _Cost()
        for op in comp.ops:
            base_kind = op.kind[:-6] if op.kind.endswith("-start") \
                else op.kind
            if base_kind in _COLLECTIVE_KINDS and \
                    not op.kind.endswith("-done"):
                _, rb = _shape_elems_bytes(op.result)
                g = _group_size(op.line)
                cost.coll[base_kind] += _collective_wire_bytes(
                    base_kind, rb, g)
                cost.coll_ops[base_kind] += 1
            if op.kind == "dot":
                cost.flops += _dot_flops(op, comp)
            if op.kind == "while":
                refs = dict(re.findall(
                    r"(condition|body)=%([\w\.\-]+)", op.line))
                body_cost = visit(refs.get("body", ""))
                cond = comps.get(refs.get("condition", ""))
                trips = _trip_count(cond) if cond else 1
                cost.add(body_cost, trips)
                continue
            if op.kind == "conditional":
                bm = _BRANCHES_RE.search(op.line)
                if bm:
                    branches = [visit(b.strip().lstrip("%"))
                                for b in bm.group(1).split(",")]
                    if branches:
                        best = max(branches, key=lambda c: c.flops)
                        cost.add(best)
                continue
            for s in _ATTR_COMP_RE.findall(op.line):
                # fusion internals' bytes are NOT HBM traffic; count only
                # their flops (and collectives, which can't fuse anyway)
                cost.add(visit(s), with_bytes=(op.kind != "fusion"))
            # kernel-level bytes: output + TOUCHED operand bytes
            if op.kind in ("constant", "parameter", "get-tuple-element",
                           "tuple", "bitcast", "copy-start", "copy-done"):
                continue
            _, ob = _shape_elems_bytes(op.result)
            if op.kind in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced region (≈ output size)
                cost.bytes += 2 * ob
                continue
            if op.kind in ("dynamic-update-slice", "scatter"):
                # in-place: writes only the update region (2nd operand)
                argtext = op.line.split("(", 1)[1]
                args = _ARGS_RE.findall(argtext)
                upd = 0
                if len(args) >= 2:
                    t = comp.shapes.get(args[1])
                    if t:
                        _, upd = _shape_elems_bytes(t)
                cost.bytes += 2 * upd
                continue
            cost.bytes += ob
            argtext = op.line.split("(", 1)[1]
            for a in _ARGS_RE.findall(argtext):
                t = comp.shapes.get(a)
                if t:
                    _, ab = _shape_elems_bytes(t)
                    cost.bytes += ab
        memo[name] = cost
        return cost

    c = visit(entry)
    return {"flops": c.flops, "bytes": c.bytes,
            "collective_bytes": {k: int(v) for k, v in c.coll.items()},
            "collective_ops": dict(c.coll_ops)}
