"""Aggregate dry-run JSON records into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m repro.analysis.report experiments/dryrun
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List


def load(dirname: str) -> List[dict]:
    out = []
    for fn in sorted(os.listdir(dirname)):
        if fn.endswith(".json"):
            with open(os.path.join(dirname, fn)) as f:
                out.append(json.load(f))
    return out


def fmt_ms(s: float) -> str:
    return f"{s * 1e3:9.1f}"


def table(records: List[dict], mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | mem/dev GiB | compute ms | memory ms | "
        "collective ms | dominant | useful-FLOPs | roofline-frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    skips = []
    for r in records:
        if r["mesh"] != mesh:
            continue
        if r.get("skip"):
            skips.append(f"| {r['arch']} | {r['shape']} | — skipped: "
                         f"{r['skip']} |")
            continue
        if not r["ok"]:
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED |")
            continue
        rf = r["roofline"]
        mem = r["memory"]["total_per_device"] / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mem:.2f} "
            f"| {fmt_ms(rf['compute_s'])} | {fmt_ms(rf['memory_s'])} "
            f"| {fmt_ms(rf['collective_s'])} | {rf['dominant']} "
            f"| {rf['useful_flops_ratio']:.2f} "
            f"| {rf['roofline_fraction']:.3f} |")
    return "\n".join(lines + [""] + skips)


def summary(records: List[dict]) -> Dict[str, int]:
    ok = sum(1 for r in records if r["ok"] and not r.get("skip"))
    skip = sum(1 for r in records if r.get("skip"))
    fail = sum(1 for r in records if not r["ok"])
    return {"ok": ok, "skip": skip, "fail": fail}


def worst_cells(records: List[dict], mesh: str = "16x16", n: int = 5):
    rows = [r for r in records
            if r["mesh"] == mesh and r["ok"] and not r.get("skip")
            and r["roofline"]["compute_s"] > 1e-5]
    rows.sort(key=lambda r: r["roofline"]["roofline_fraction"])
    return rows[:n]


def most_collective_bound(records: List[dict], mesh: str = "16x16", n: int = 5):
    rows = [r for r in records
            if r["mesh"] == mesh and r["ok"] and not r.get("skip")]
    rows.sort(key=lambda r: -(r["roofline"]["collective_s"]
                              / max(r["roofline"]["compute_s"], 1e-9)))
    return rows[:n]


def main() -> None:
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    records = load(d)
    print(f"records: {summary(records)}\n")
    for mesh in ("16x16", "2x16x16"):
        print(f"## mesh {mesh}\n")
        print(table(records, mesh))
        print()
    print("### worst roofline fraction (single-pod)")
    for r in worst_cells(records):
        rf = r["roofline"]
        print(f"  {r['arch']}/{r['shape']}: frac={rf['roofline_fraction']:.3f}"
              f" dominant={rf['dominant']}")
    print("### most collective-bound (single-pod)")
    for r in most_collective_bound(records):
        rf = r["roofline"]
        print(f"  {r['arch']}/{r['shape']}: collective/compute="
              f"{rf['collective_s'] / max(rf['compute_s'], 1e-9):.1f}")


if __name__ == "__main__":
    main()
