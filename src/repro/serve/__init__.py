from .engine import Completion, Request, ServingEngine  # noqa: F401
