"""Batched serving engine: request queue -> padded batch -> prefill ->
decode loop.  The end-to-end inference driver for examples/serve_lm.py.

Serving style is static batching with greedy sampling (temperature
optional): requests are grouped into batches of `batch_size`, prompts are
left-padded to a common length, prefill fills the KV cache (ring-buffer
bounded for sliding-window archs), then one decode_step per generated
token.  Finished sequences are masked out (EOS or budget).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # [S] int32
    max_new_tokens: int = 32
    extras: Optional[Dict[str, np.ndarray]] = None   # patch/audio embeds


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray
    prompt_len: int
    latency_s: float


class ServingEngine:
    def __init__(self, model: Model, params: Any, *, batch_size: int = 4,
                 max_len: int = 512, eos_id: int = -1,
                 dtype=jnp.float32):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        self.dtype = dtype
        self.queue: List[Request] = []
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------------ #
    def run(self) -> List[Completion]:
        done: List[Completion] = []
        while self.queue:
            batch = self.queue[:self.batch_size]
            self.queue = self.queue[self.batch_size:]
            done.extend(self._run_batch(batch))
        return done

    def _run_batch(self, reqs: Sequence[Request]) -> List[Completion]:
        t0 = time.perf_counter()
        bsz = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        budget = max(r.max_new_tokens for r in reqs)
        # left-pad so the last prompt token is aligned at plen-1
        toks = np.zeros((bsz, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt

        cfg = self.model.cfg
        prefix = cfg.num_image_tokens if cfg.family == "vlm" else 0
        state = self.model.init_decode_state(
            bsz, min(self.max_len, plen + prefix + budget + 1), self.dtype)
        feed: Dict[str, jax.Array] = {"tokens": jnp.asarray(toks)}
        if reqs[0].extras:
            for k, v in reqs[0].extras.items():
                feed[k] = jnp.stack(
                    [jnp.asarray(r.extras[k]) for r in reqs])
        state, logits = self._prefill(self.params, feed, state)

        out = [list(r.prompt) for r in reqs]
        alive = np.ones(bsz, bool)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for step in range(budget):
            for i in range(bsz):
                if alive[i]:
                    t = int(tok[i, 0])
                    out[i].append(t)
                    if t == self.eos_id or \
                            len(out[i]) - len(reqs[i].prompt) >= \
                            reqs[i].max_new_tokens:
                        alive[i] = False
            if not alive.any() or step == budget - 1:
                break
            idx = jnp.asarray(plen + prefix + step, jnp.int32)
            logits, state = self._decode(self.params, tok, state, idx)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)

        dt = time.perf_counter() - t0
        return [Completion(uid=r.uid, tokens=np.asarray(out[i], np.int32),
                           prompt_len=len(r.prompt), latency_s=dt)
                for i, r in enumerate(reqs)]
