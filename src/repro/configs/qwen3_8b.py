"""Qwen3-8B (dense).  [hf:Qwen/Qwen3-8B]
36L d_model=4096 32H (GQA kv=8, head_dim=128) d_ff=12288 vocab=151936,
per-head qk-norm."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=12288, vocab_size=151936,
    qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=False,
    max_seq_len=131_072,
)
