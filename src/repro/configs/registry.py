"""Architecture registry: the 10 assigned architectures, their shape grid
(40 cells), and the documented long_500k skips (DESIGN.md §5)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.models.common import ModelConfig

from .shapes import ALL_SHAPES, ShapeSpec
from .mistral_nemo_12b import CONFIG as MISTRAL_NEMO_12B
from .gemma2_2b import CONFIG as GEMMA2_2B
from .command_r_35b import CONFIG as COMMAND_R_35B
from .qwen3_8b import CONFIG as QWEN3_8B
from .qwen2_moe_a2_7b import CONFIG as QWEN2_MOE_A2_7B
from .mixtral_8x7b import CONFIG as MIXTRAL_8X7B
from .paligemma_3b import CONFIG as PALIGEMMA_3B
from .whisper_medium import CONFIG as WHISPER_MEDIUM
from .zamba2_1_2b import CONFIG as ZAMBA2_1_2B
from .mamba2_780m import CONFIG as MAMBA2_780M

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in (
        MISTRAL_NEMO_12B, GEMMA2_2B, COMMAND_R_35B, QWEN3_8B,
        QWEN2_MOE_A2_7B, MIXTRAL_8X7B, PALIGEMMA_3B, WHISPER_MEDIUM,
        ZAMBA2_1_2B, MAMBA2_780M)
}

# archs whose decode state stays bounded (or O(1)) at 500k context
_LONG_CONTEXT_OK = {"mixtral-8x7b", "zamba2-1.2b", "mamba2-780m"}

_SKIP_REASONS = {
    "mistral-nemo-12b": "pure full attention: unbounded 500k KV per layer",
    "command-r-35b": "pure full attention: unbounded 500k KV per layer",
    "qwen3-8b": "pure full attention: unbounded 500k KV per layer",
    "qwen2-moe-a2.7b": "pure full attention: unbounded 500k KV per layer",
    "paligemma-3b": "full-attention prefix LM: unbounded 500k KV",
    "gemma2-2b": "alternating global layers are full attention at 500k",
    "whisper-medium": "decoder hard-capped at 448 positions by design",
}


def skip_reason(arch: str, shape: ShapeSpec) -> Optional[str]:
    """None = the (arch, shape) cell runs; else the documented skip."""
    if shape.name == "long_500k" and arch not in _LONG_CONTEXT_OK:
        return _SKIP_REASONS[arch]
    return None


def cells() -> List[Tuple[ModelConfig, ShapeSpec, Optional[str]]]:
    """The full 40-cell grid with skip annotations."""
    out = []
    for cfg in ARCHS.values():
        for shape in ALL_SHAPES:
            out.append((cfg, shape, skip_reason(cfg.name, shape)))
    return out


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(name: str, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    base = get_config(name)
    small = dict(
        num_layers=max(2, (2 if not base.hybrid_attn_every else 4)),
        d_model=64, d_ff=128, vocab_size=256, max_seq_len=512,
        head_dim=16,
    )
    if base.num_heads:
        small["num_heads"] = 4
        small["num_kv_heads"] = min(base.num_kv_heads, 2) or 1
        if base.num_kv_heads == base.num_heads:
            small["num_kv_heads"] = 4
    if base.num_experts:
        small.update(num_experts=4, num_experts_per_tok=2, moe_d_ff=64,
                     num_shared_experts=min(base.num_shared_experts, 1))
    if base.ssm_state_dim:
        small.update(ssm_state_dim=16, ssm_head_dim=16, ssm_chunk=16)
    if base.hybrid_attn_every:
        small.update(hybrid_attn_every=2, num_layers=4)
    if base.is_encoder_decoder:
        small.update(encoder_layers=2, encoder_seq=64)
    if base.num_image_tokens:
        small.update(num_image_tokens=16)
    if base.sliding_window:
        small.update(sliding_window=64)
    small.update(overrides)
    return dataclasses.replace(base, **small)
