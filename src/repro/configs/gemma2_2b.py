"""Gemma2-2B (dense).  [arXiv:2408.00118]
26L d_model=2304 8H (GQA kv=4, head_dim=256) d_ff=9216 vocab=256000.
Alternating local(4096)/global attention, attn logit softcap 50, final
logit softcap 30, sandwich norms, sqrt(d)-scaled embeddings, GeGLU."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000,
    sliding_window=4096, local_global_pattern=True,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    sandwich_norm=True, scale_embeddings=True, activation="gelu",
    tie_embeddings=True, max_seq_len=8192,
)
