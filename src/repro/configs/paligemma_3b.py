"""PaliGemma-3B backbone.  [arXiv:2407.07726]
18L d_model=2048 8H (MQA kv=1, head_dim=256) d_ff=16384 vocab=257216.
SigLIP vision tower is a STUB: input_specs() provides 256 precomputed patch
embeddings; prefix-LM mask is bidirectional over the image prefix."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216,
    num_image_tokens=256, scale_embeddings=True, activation="gelu",
    tie_embeddings=True, max_seq_len=8192,
)
