from .shapes import (ALL_SHAPES, DECODE_32K, LONG_500K,  # noqa: F401
                     PREFILL_32K, TRAIN_4K, ShapeSpec, shape_by_name)
from .registry import (ARCHS, cells, get_config, reduced_config,  # noqa: F401
                       skip_reason)
