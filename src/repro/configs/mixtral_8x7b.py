"""Mixtral-8x7B.  [arXiv:2401.04088]
32L d_model=4096 32H (GQA kv=8, head_dim=128) vocab=32000.
MoE: 8 experts (d_ff 14336 each) top-2; sliding-window attention (4096) --
the window-bounded KV cache is why this arch runs the long_500k cell."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    num_experts=8, num_experts_per_tok=2, moe_d_ff=14336,
    sliding_window=4096, tie_embeddings=False, max_seq_len=524_288,
)
