"""Mamba2-780m (pure SSM / SSD).  [arXiv:2405.21060; unverified]
48L d_model=1536 (attn-free) vocab=50280, ssm_state=128, expand 2
(d_inner=3072, 48 heads of dim 64).  State-space duality: chunked parallel
scan for train/prefill, O(1) recurrent state for decode -> long_500k runs."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, head_dim=64,
    ssm_state_dim=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=512,
    tie_embeddings=True, max_seq_len=524_288,
)
