"""Command-R v01 (35B dense).  [hf:CohereForAI/c4ai-command-r-v01; unverified]
40L d_model=8192 64H (GQA kv=8, head_dim=128) d_ff=22528 vocab=256000,
no biases, tied embeddings."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22528, vocab_size=256000,
    rope_theta=8_000_000.0, tie_embeddings=True, max_seq_len=131_072,
)
