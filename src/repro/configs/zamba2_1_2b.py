"""Zamba2-1.2B (hybrid Mamba2 + shared attention).  [arXiv:2411.15242]
38 Mamba2 layers d_model=2048 (ssm_state=64) with one SHARED transformer
block (32H kv=32, d_ff=8192) applied every 6 layers (parameters reused).
O(1) SSM state + small shared-attn KV -> runs the long_500k cell."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    ssm_state_dim=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    hybrid_attn_every=6, tie_embeddings=True, max_seq_len=524_288,
)
