"""The four assigned input shapes.  `train_*` lowers train_step; `prefill_*`
lowers the prefill step; `decode_*`/`long_*` lower serve_step (one new token
against a KV cache of seq_len)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeSpec("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeSpec("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeSpec("long_500k", "decode", 524_288, 1)

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                     LONG_500K)


def shape_by_name(name: str) -> ShapeSpec:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
