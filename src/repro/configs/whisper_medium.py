"""Whisper-medium.  [arXiv:2212.04356; unverified]
Enc-dec: 24+24L d_model=1024 16H (kv=16, head_dim=64) d_ff=4096 vocab=51865.
Conv audio frontend is a STUB: input_specs() provides 1500 precomputed frame
embeddings.  Plain (non-gated) GELU MLPs."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51865,
    is_encoder_decoder=True, encoder_layers=24, encoder_seq=1500,
    mlp_variant="plain", activation="gelu", tie_embeddings=True,
    max_seq_len=448,
)
