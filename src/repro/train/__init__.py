from .optimizer import (AdamWConfig, AdamWState, adamw_update,  # noqa: F401
                        init_adamw, lr_schedule, global_norm)
from .train_step import (TrainConfig, init_train_state,  # noqa: F401
                         loss_and_grad, make_train_step)
from .data import DataConfig, host_batch_slice, make_global_batch  # noqa: F401
from . import checkpoint  # noqa: F401
from .fault_tolerance import (FaultInjector, LinkFault,  # noqa: F401
                              StragglerMonitor, TrainSupervisor,
                              elastic_plan)
