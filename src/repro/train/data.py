"""Deterministic synthetic data pipeline with host-side sharding.

Production shape: each host materialises only its slice of the global batch
(`host_batch_slice`), and `make_global_batch` assembles a sharded
jax.Array via `jax.make_array_from_callback` — the same call pattern a real
multi-host loader uses, so swapping in a tokenised dataset changes one
function.  Batches are a pure function of (seed, step): restart-safe and
bitwise reproducible across checkpoint resume (the fault-tolerance tests
rely on this).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # frontend stubs (vlm / audio)
    num_image_tokens: int = 0
    encoder_seq: int = 0
    d_model: int = 0


def _rng_for(cfg: DataConfig, step: int, name: str) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, hash(name) & 0x7FFFFFFF]))


def host_batch_slice(cfg: DataConfig, step: int, lo: int, hi: int
                     ) -> Dict[str, np.ndarray]:
    """Rows [lo, hi) of the global batch for `step` — what one host loads.
    Generated row-wise so any slicing of the global batch is consistent."""
    out: Dict[str, np.ndarray] = {}
    rows = []
    for r in range(lo, hi):
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, r]))
        rows.append(rng.integers(0, cfg.vocab_size, cfg.seq_len,
                                 dtype=np.int32))
    out["tokens"] = np.stack(rows) if rows else \
        np.zeros((0, cfg.seq_len), np.int32)
    if cfg.num_image_tokens:
        rng = _rng_for(cfg, step, "patch")
        out["patch_embed"] = rng.standard_normal(
            (hi - lo, cfg.num_image_tokens, cfg.d_model),
            dtype=np.float32) * 0.02
    if cfg.encoder_seq:
        rng = _rng_for(cfg, step, "audio")
        out["audio_embed"] = rng.standard_normal(
            (hi - lo, cfg.encoder_seq, cfg.d_model),
            dtype=np.float32) * 0.02
    return out


def make_global_batch(cfg: DataConfig, step: int, mesh: Mesh,
                      batch_axes: Tuple[str, ...] = ("data",)
                      ) -> Dict[str, jax.Array]:
    """Assemble the sharded global batch; each addressable shard is
    materialised independently (multi-host safe)."""
    specs = {"tokens": PartitionSpec(batch_axes)}
    shapes = {"tokens": (cfg.global_batch, cfg.seq_len)}
    if cfg.num_image_tokens:
        specs["patch_embed"] = PartitionSpec(batch_axes)
        shapes["patch_embed"] = (cfg.global_batch, cfg.num_image_tokens,
                                 cfg.d_model)
    if cfg.encoder_seq:
        specs["audio_embed"] = PartitionSpec(batch_axes)
        shapes["audio_embed"] = (cfg.global_batch, cfg.encoder_seq,
                                 cfg.d_model)

    out = {}
    for name, spec in specs.items():
        sharding = NamedSharding(mesh, spec)
        shape = shapes[name]

        def cb(index, name=name, shape=shape):
            rows = index[0]
            lo = rows.start or 0
            hi = rows.stop if rows.stop is not None else shape[0]
            data = host_batch_slice(cfg, step, lo, hi)[name]
            rest = index[1:]
            return data[(slice(None),) + tuple(rest)]

        out[name] = jax.make_array_from_callback(shape, sharding, cb)
    return out
