"""AdamW on parameter pytrees (no optax dependency).

State leaves (`mu`, `nu`) mirror the parameter tree, so any parameter
PartitionSpec applies verbatim to the optimizer state; ZeRO-1 additionally
shards them along the data axis (launch/sharding.py decides the specs —
this module is sharding-agnostic).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Params
    nu: Params
    # f32 master copy when the live params are bf16 ("mixed-precision
    # optimizer" layout — perf iteration B1: grads then reduce in bf16,
    # halving DP gradient wire bytes).  None => params are the master.
    master: Optional[Params] = None


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def init_adamw(params: Params, keep_master: bool = False) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params) \
        if keep_master else None
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros), master=master)


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads: Params, state: AdamWState,
                 params: Params) -> Tuple[Params, AdamWState, Dict[str, Any]]:
    """One AdamW step with global-norm clipping and decoupled weight decay.
    When state.master is set, the f32 master is updated and the (bf16)
    params are re-derived from it.  Returns (new_params, new_state,
    metrics)."""
    base = state.master if state.master is not None else params
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    step = state.step + 1
    lr = lr_schedule(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mu_hat = mu / b1c
        nu_hat = nu / b2c
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) \
            if p.ndim >= 2 else 0.0   # no decay on norms/biases
        new_p = p.astype(jnp.float32) - lr * (delta + decay)
        return new_p.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(base)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_base = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    if state.master is not None:
        new_params = jax.tree.map(
            lambda b, p: b.astype(p.dtype), new_base, params)
        return new_params, AdamWState(step, new_mu, new_nu,
                                      new_base), metrics
    return new_base, AdamWState(step, new_mu, new_nu), metrics
