"""The training step: loss -> grad -> (optional tree-pipeline allreduce) ->
AdamW, with microbatch gradient accumulation and a dtype policy.

Two collective modes:

* "xla"      — grads flow through pjit/GSPMD; XLA inserts its own
               all-reduces.  This is the stock baseline.
* "pipeline" — gradients are reduced with the paper's bandwidth-optimal
               tree-pipeline schedules (repro.comms) inside shard_map.
               Used by the shard_map training driver and the perf loop.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.model_zoo import Model
from .optimizer import AdamWConfig, AdamWState, adamw_update, init_adamw


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1            # grad accumulation steps
    compute_dtype: Any = jnp.float32  # bf16 on TPU
    collectives: str = "xla"         # xla | pipeline
    # optional: pin the bf16 cast of each param to its sharding so FSDP
    # weight all-gathers (and the transposed grad reductions) move bf16
    # wire bytes instead of f32 (perf iteration A2, EXPERIMENTS.md §Perf)
    cast_sharding: Any = None        # pytree of NamedSharding or None


def cast_params(params, dtype, cast_sharding=None):
    cast = jax.tree.map(
        lambda p: p.astype(dtype) if p.dtype in
        (jnp.float32, jnp.bfloat16, jnp.float16) else p, params)
    if cast_sharding is not None:
        cast = jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s)
            if s is not None else x, cast, cast_sharding)
    return cast


def loss_and_grad(model: Model, params, batch,
                  cfg: TrainConfig) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (loss, grads, raw_token_loss); microbatched if configured."""
    def loss_fn(p, b):
        cast = cast_params(p, cfg.compute_dtype, cfg.cast_sharding)
        total, token_loss = model.loss(cast, b)
        return total, token_loss

    if cfg.microbatches <= 1:
        (loss, tok), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, grads, tok

    # split the per-device batch into microbatches and scan-accumulate
    def split(x):
        b = x.shape[0]
        assert b % cfg.microbatches == 0, \
            f"batch {b} not divisible by microbatches {cfg.microbatches}"
        return x.reshape((cfg.microbatches, b // cfg.microbatches)
                         + x.shape[1:])

    micro = jax.tree.map(split, batch)

    def body(carry, mb):
        acc_loss, acc_tok, acc_g = carry
        (loss, tok), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb)
        acc_g = jax.tree.map(jnp.add, acc_g, grads)
        return (acc_loss + loss, acc_tok + tok, acc_g), None

    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, tok, grads), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(()), zero_g), micro)
    n = cfg.microbatches
    return loss / n, jax.tree.map(lambda g: g / n, grads), tok / n


def make_train_step(model: Model, cfg: TrainConfig,
                    grad_reduce: Optional[Callable[[Any], Any]] = None):
    """Build the jit-able train_step(params, opt_state, batch).

    grad_reduce: optional callable applied to the gradient pytree before the
    optimizer — the hook where the paper's tree-pipeline allreduce plugs in
    (inside shard_map).  Under pure pjit, leave None (XLA reduces via the
    sharding constraints)."""

    def train_step(params, opt_state: AdamWState, batch
                   ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
        loss, grads, tok = loss_and_grad(model, params, batch, cfg)
        if grad_reduce is not None:
            grads = grad_reduce(grads)
            loss = grad_reduce(loss)  # average the scalar too
        new_params, new_state, metrics = adamw_update(
            cfg.optimizer, grads, opt_state, params)
        metrics = dict(metrics, loss=loss, token_loss=tok)
        return new_params, new_state, metrics

    return train_step


def init_train_state(model: Model, rng: jax.Array,
                     param_dtype=jnp.float32) -> Tuple[Any, AdamWState]:
    params = model.init(rng, param_dtype)
    return params, init_adamw(params)
