"""Checkpointing: atomic, sharded, resumable, optionally async.

Layout:
    <dir>/step_000123/
        manifest.json        # tree structure, shapes, dtypes, step
        arrays.npz           # flattened leaves (addressable data)
    <dir>/LATEST             # atomic pointer file

Writes go to a tmp dir + os.replace (atomic on POSIX), so a crash mid-save
never corrupts the latest checkpoint — the fault-tolerance loop relies on
this.  `save_async` runs the serialisation on a background thread with the
arrays already fetched to host (so the train loop only blocks for the
device->host copy).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Params = Any


def _flatten_with_paths(tree: Params) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Params) -> str:
    """Synchronous atomic save; returns the checkpoint path."""
    leaves = _flatten_with_paths(tree)
    host = {k: np.asarray(v) for k, v in leaves}
    return _write(ckpt_dir, step, tree, host)


_pending: List[threading.Thread] = []


def save_async(ckpt_dir: str, step: int, tree: Params) -> threading.Thread:
    """Fetch to host synchronously, serialise on a background thread."""
    leaves = _flatten_with_paths(tree)
    host = {k: np.asarray(v) for k, v in leaves}   # device->host blocks here

    t = threading.Thread(target=_write, args=(ckpt_dir, step, tree, host),
                         daemon=True)
    t.start()
    _pending.append(t)
    return t


def wait_pending() -> None:
    while _pending:
        _pending.pop().join()


def _write(ckpt_dir: str, step: int, tree: Params,
           host: Dict[str, np.ndarray]) -> str:
    name = f"step_{step:09d}"
    final = os.path.join(ckpt_dir, name)
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in host.items()},
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **host)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    # atomic LATEST pointer
    ptr_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(name)
    os.replace(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    path = os.path.join(ckpt_dir, name)
    if not os.path.isdir(path):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, template: Params,
            step: Optional[int] = None) -> Tuple[Params, int]:
    """Restore into the structure of `template` (shapes are validated).
    Re-sharding happens on the caller side by device_put with the desired
    sharding — elastic restarts restore on a different mesh this way."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    arrays = np.load(os.path.join(path, "arrays.npz"))
    leaves = _flatten_with_paths(template)
    restored = []
    for key, leaf in leaves:
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        want = tuple(getattr(leaf, "shape", ()))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"leaf {key}: checkpoint shape {arr.shape} != {want}")
        restored.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, restored), step


def all_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                  if d.startswith("step_") and not d.endswith(".tmp"))


def gc_old(ckpt_dir: str, keep: int = 3) -> None:
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"),
                      ignore_errors=True)
