"""Fault tolerance: supervised training loop with checkpoint/restart,
straggler detection, and an elastic re-mesh plan.

At thousand-node scale the assumptions are: (a) some host WILL fail
mid-run, (b) some host WILL run slow (thermal, network), (c) the replacement
cluster may have a different device count.  The pieces here:

* `TrainSupervisor.run` — steps the train function, checkpoints every
  `ckpt_every` (async), and on any exception restores the latest checkpoint
  and continues (`max_restarts` budget).  Data is a pure function of step,
  so resume is bitwise-deterministic.
* `StragglerMonitor` — EWMA of step wall-time; flags steps slower than
  `threshold`× the running mean.  On TPU pods the mitigation is re-shard /
  exclude via the elastic plan below (here: logged + counted, hook exposed).
* `elastic_plan` — given old/new device counts, emits the re-mesh shape and
  whether the global batch must be re-split; checkpoint restore +
  device_put with the new NamedSharding completes the elastic restart
  (checkpoints are host-side full arrays, so any mesh can load them).
* `LinkFault` / `FaultInjector` — a typed mid-step failure for a dead
  fabric link.  Unlike a host crash, the training state is intact when a
  link dies (the step raised before committing), so `TrainSupervisor`
  routes it to the `on_link_fault` hook — online schedule repair + hot
  swap (`repro.comms.mesh_axes.CollectiveContext.hot_swap`) — and retries
  the *same* step without restoring a checkpoint.  The injector exists so
  tests and the launch drivers (``--inject-fault step:u-v``) can exercise
  that path deterministically.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from . import checkpoint as ckpt


class LinkFault(RuntimeError):
    """A fabric link (u, v) died mid-step.  Carries the transform text the
    repair path needs (``@fail(u-v)``)."""

    def __init__(self, u: int, v: int, message: Optional[str] = None):
        super().__init__(message or f"link {u}-{v} failed")
        self.u = int(u)
        self.v = int(v)

    @property
    def transform_text(self) -> str:
        return f"@fail({self.u}-{self.v})"


@dataclasses.dataclass
class FaultInjector:
    """Raise one `LinkFault` when training reaches `at_step` — the
    deterministic stand-in for a mid-run link failure."""
    at_step: int
    u: int
    v: int
    fired: bool = False

    @classmethod
    def parse(cls, text: str) -> "FaultInjector":
        """``"step:u-v"`` — e.g. ``"3:0-1"`` fails link 0-1 at step 3."""
        try:
            step_s, link = text.split(":", 1)
            u_s, v_s = link.split("-", 1)
            return cls(at_step=int(step_s), u=int(u_s), v=int(v_s))
        except ValueError as e:
            raise ValueError(
                f"malformed fault spec {text!r} (expected 'step:u-v')") from e

    def check(self, step: int) -> None:
        if not self.fired and step == self.at_step:
            self.fired = True
            raise LinkFault(self.u, self.v)


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.2
    threshold: float = 2.0
    ewma: Optional[float] = None
    flagged: List[Tuple[int, float]] = dataclasses.field(default_factory=list)
    on_straggler: Optional[Callable[[int, float], None]] = None

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = (self.ewma is not None
                        and dt > self.threshold * self.ewma)
        if is_straggler:
            self.flagged.append((step, dt))
            if self.on_straggler:
                self.on_straggler(step, dt)
        # Clamp outliers to threshold× the mean instead of dropping them:
        # one spike still can't swamp the EWMA, but a *persistent* slowdown
        # walks the mean up geometrically until the new speed stops being
        # flagged (dropping flagged samples froze the mean at the old speed
        # and flagged every step forever).
        if self.ewma is None:
            self.ewma = dt
        else:
            capped = min(dt, self.threshold * self.ewma)
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * capped
        return is_straggler


def elastic_plan(old_devices: int, new_devices: int, global_batch: int,
                 model_parallel: int) -> Dict[str, Any]:
    """Re-mesh plan after losing/gaining hosts.  Keeps model parallelism
    fixed (param layout survives), resizes the data axis, and adjusts
    microbatching so the global batch is preserved when divisibility
    allows."""
    if new_devices % model_parallel:
        raise ValueError(
            f"{new_devices} devices cannot keep model_parallel="
            f"{model_parallel}")
    new_data = new_devices // model_parallel
    plan = {
        "mesh_shape": (new_data, model_parallel),
        "data_axis": new_data,
        "global_batch": global_batch,
        "microbatch_scale": 1,
    }
    if global_batch % new_data:
        # keep global batch by accumulating: the smallest scale with
        # new_data | global_batch·scale is new_data / gcd(global_batch,
        # new_data) — each of the `scale` accumulation passes feeds
        # global_batch·scale/new_data examples per data shard, and the
        # summed gradient covers exactly `global_batch` examples.
        plan["microbatch_scale"] = new_data // math.gcd(global_batch,
                                                        new_data)
    return plan


@dataclasses.dataclass
class TrainSupervisor:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    #: link faults take this path instead of checkpoint restore: the hook
    #: (typically `CollectiveContext.hot_swap` + logging) repairs the
    #: communication schedules for the degraded fabric, and the SAME step
    #: is retried on the intact state — no work is lost.  Budgeted
    #: separately from `max_restarts` (a repaired fabric is a recovery,
    #: not a crash).
    on_link_fault: Optional[Callable[[LinkFault], None]] = None
    max_link_faults: int = 3
    monitor: StragglerMonitor = dataclasses.field(
        default_factory=StragglerMonitor)

    def run(self, *, state: Any, num_steps: int,
            step_fn: Callable[[int, Any], Tuple[Any, Dict[str, Any]]],
            start_step: int = 0,
            log_every: int = 10,
            log: Callable[[str], None] = print) -> Tuple[Any, int]:
        """step_fn(step, state) -> (state, metrics).  Returns final state.

        Any exception triggers restore-from-latest + replay (data is pure
        in step, so replayed steps are identical) — except a `LinkFault`
        with `on_link_fault` set, which repairs in place and retries the
        step without touching checkpoints."""
        step = start_step
        restarts = 0
        link_faults = 0
        while step < num_steps:
            try:
                t0 = time.perf_counter()
                state, metrics = step_fn(step, state)
                dt = time.perf_counter() - t0
                if self.monitor.observe(step, dt):
                    log(f"[ft] straggler at step {step}: {dt:.3f}s "
                        f"(ewma {self.monitor.ewma:.3f}s)")
                if log_every and step % log_every == 0:
                    loss = metrics.get("loss")
                    log(f"step {step}: loss={float(loss):.4f} dt={dt:.3f}s"
                        if loss is not None else f"step {step}: dt={dt:.3f}s")
                step += 1
                if step % self.ckpt_every == 0 or step == num_steps:
                    ckpt.save_async(self.ckpt_dir, step, state)
                    ckpt.gc_old(self.ckpt_dir, self.keep)
            except KeyboardInterrupt:
                raise
            except LinkFault as e:
                if self.on_link_fault is None:
                    raise       # no repair path configured: a real crash
                link_faults += 1
                if link_faults > self.max_link_faults:
                    raise RuntimeError(
                        f"exceeded {self.max_link_faults} link faults") from e
                log(f"[ft] link fault at step {step} ({e}); repairing "
                    f"schedules in place (fault {link_faults}/"
                    f"{self.max_link_faults})")
                self.on_link_fault(e)
                # state is intact (the step raised before committing):
                # retry the same step on the repaired fabric, no restore
            except Exception as e:  # noqa: BLE001 — any failure: restart
                restarts += 1
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e
                ckpt.wait_pending()
                last = ckpt.latest_step(self.ckpt_dir)
                if last is None:
                    raise RuntimeError("failure before first checkpoint") \
                        from e
                log(f"[ft] step {step} failed ({type(e).__name__}: {e}); "
                    f"restoring step {last} (restart {restarts}/"
                    f"{self.max_restarts})")
                state, step = ckpt.restore(self.ckpt_dir, state, step=last)
        ckpt.wait_pending()
        return state, step
