"""Fault tolerance: supervised training loop with checkpoint/restart,
straggler detection, and an elastic re-mesh plan.

At thousand-node scale the assumptions are: (a) some host WILL fail
mid-run, (b) some host WILL run slow (thermal, network), (c) the replacement
cluster may have a different device count.  The pieces here:

* `TrainSupervisor.run` — steps the train function, checkpoints every
  `ckpt_every` (async), and on any exception restores the latest checkpoint
  and continues (`max_restarts` budget).  Data is a pure function of step,
  so resume is bitwise-deterministic.
* `StragglerMonitor` — EWMA of step wall-time; flags steps slower than
  `threshold`× the running mean.  On TPU pods the mitigation is re-shard /
  exclude via the elastic plan below (here: logged + counted, hook exposed).
* `elastic_plan` — given old/new device counts, emits the re-mesh shape and
  whether the global batch must be re-split; checkpoint restore +
  device_put with the new NamedSharding completes the elastic restart
  (checkpoints are host-side full arrays, so any mesh can load them).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from . import checkpoint as ckpt


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.2
    threshold: float = 2.0
    ewma: Optional[float] = None
    flagged: List[Tuple[int, float]] = dataclasses.field(default_factory=list)
    on_straggler: Optional[Callable[[int, float], None]] = None

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = (self.ewma is not None
                        and dt > self.threshold * self.ewma)
        if is_straggler:
            self.flagged.append((step, dt))
            if self.on_straggler:
                self.on_straggler(step, dt)
        # EWMA excludes outliers so one straggler doesn't mask the next
        if not is_straggler:
            self.ewma = dt if self.ewma is None else \
                (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


def elastic_plan(old_devices: int, new_devices: int, global_batch: int,
                 model_parallel: int) -> Dict[str, Any]:
    """Re-mesh plan after losing/gaining hosts.  Keeps model parallelism
    fixed (param layout survives), resizes the data axis, and adjusts
    microbatching so the global batch is preserved when divisibility
    allows."""
    if new_devices % model_parallel:
        raise ValueError(
            f"{new_devices} devices cannot keep model_parallel="
            f"{model_parallel}")
    new_data = new_devices // model_parallel
    plan = {
        "mesh_shape": (new_data, model_parallel),
        "data_axis": new_data,
        "global_batch": global_batch,
        "microbatch_scale": 1,
    }
    if global_batch % new_data:
        # keep global batch by accumulating: smallest integer scale s.t.
        # (global_batch / micro) divides the data axis
        scale = math.lcm(new_data, global_batch) // global_batch
        plan["microbatch_scale"] = scale
    return plan


@dataclasses.dataclass
class TrainSupervisor:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    monitor: StragglerMonitor = dataclasses.field(
        default_factory=StragglerMonitor)

    def run(self, *, state: Any, num_steps: int,
            step_fn: Callable[[int, Any], Tuple[Any, Dict[str, Any]]],
            start_step: int = 0,
            log_every: int = 10,
            log: Callable[[str], None] = print) -> Tuple[Any, int]:
        """step_fn(step, state) -> (state, metrics).  Returns final state.

        Any exception triggers restore-from-latest + replay (data is pure
        in step, so replayed steps are identical)."""
        step = start_step
        restarts = 0
        while step < num_steps:
            try:
                t0 = time.perf_counter()
                state, metrics = step_fn(step, state)
                dt = time.perf_counter() - t0
                if self.monitor.observe(step, dt):
                    log(f"[ft] straggler at step {step}: {dt:.3f}s "
                        f"(ewma {self.monitor.ewma:.3f}s)")
                if log_every and step % log_every == 0:
                    loss = metrics.get("loss")
                    log(f"step {step}: loss={float(loss):.4f} dt={dt:.3f}s"
                        if loss is not None else f"step {step}: dt={dt:.3f}s")
                step += 1
                if step % self.ckpt_every == 0 or step == num_steps:
                    ckpt.save_async(self.ckpt_dir, step, state)
                    ckpt.gc_old(self.ckpt_dir, self.keep)
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — any failure: restart
                restarts += 1
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e
                ckpt.wait_pending()
                last = ckpt.latest_step(self.ckpt_dir)
                if last is None:
                    raise RuntimeError("failure before first checkpoint") \
                        from e
                log(f"[ft] step {step} failed ({type(e).__name__}: {e}); "
                    f"restoring step {last} (restart {restarts}/"
                    f"{self.max_restarts})")
                state, step = ckpt.restore(self.ckpt_dir, state), last
                state = state[0]
        ckpt.wait_pending()
        return state, step
