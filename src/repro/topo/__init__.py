from .spec import (  # noqa: F401
    TopologySpec, TopologySpecError, TransformSpec, register_topology,
    register_transform, resolve_topology, topology_families,
    transform_names, zoo_specs,
)
from .zoo import (  # noqa: F401
    ZOO_SPECS,
    ring, bidir_ring, line, fully_connected, torus_2d, torus_3d,
    hypercube, star_switch, circulant, two_cluster_switch, fig1a,
    fig1d_ring_unwound,
    fat_tree, dragonfly, dgx_box, bcube, mesh_of_dgx,
    fail_link, degrade_link,
)
from .tpu import (  # noqa: F401
    TPU_V5E, HardwareSpec, v5e_pod_topology, multipod_topology,
    axis_topology_for_mesh,
)
