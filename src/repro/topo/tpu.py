"""TPU hardware model: v5e pod ICI torus + multi-pod DCN, roofline constants.

The container targets TPU v5e (this is the TARGET platform; the runtime here
is CPU).  Constants below feed the roofline analysis:

    compute term    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory term     = HLO_bytes / (chips * HBM_BW)
    collective term = collective_bytes / (chips * ICI_BW per link)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.core.graph import DiGraph, Edge

from .spec import register_topology


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float      # per chip, FLOP/s
    hbm_bw: float               # per chip, bytes/s
    ici_link_bw: float          # per directed ICI link, bytes/s
    dcn_bw_per_pod: float       # aggregate DCN bytes/s per pod
    hbm_bytes: float            # per chip HBM capacity
    vmem_bytes: float           # per core VMEM


TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,           # ~50 GB/s per link per the assignment
    dcn_bw_per_pod=200e9,       # 1.6 Tbit/s-class DCN per pod (model)
    hbm_bytes=16e9,
    vmem_bytes=128 * 2 ** 20,
)


# ---------------------------------------------------------------------- #
# Topology models for the schedule compiler
# ---------------------------------------------------------------------- #

@register_topology("v5e", pattern="{rows}x{cols}")
def v5e_pod_topology(rows: int = 16, cols: int = 16,
                     cap: int = 1) -> DiGraph:
    """A v5e pod is a (wrapped) 2-D ICI torus; one capacity unit == one ICI
    link (~50 GB/s).  Direct-connect: §2.2 edge splitting is a no-op here."""
    from .zoo import torus_2d
    g = torus_2d(rows, cols, cap=cap)
    return DiGraph(g.num_nodes, g.compute, g.cap, f"v5e-{rows}x{cols}")


@register_topology("multipod", pattern="{num_pods}x{nodes_per_pod}")
def multipod_topology(num_pods: int = 2, nodes_per_pod: int = 4,
                      ici_cap: int = 10, dcn_cap: int = 1) -> DiGraph:
    """Pod-level multi-pod model: per-pod ICI modelled as a local switch with
    fat links (ici_cap per node), pods joined through a DCN switch with
    dcn_cap per node.  Structurally identical to the paper's Fig 1a — the
    cluster cut is the bottleneck, and edge splitting beats ring unwinding
    by ici_cap/... (4x in the paper's numbers).

    Node ids: compute 0..P*n-1, DCN switch = P*n, pod switches follow."""
    n = num_pods * nodes_per_pod
    dcn = n
    edges: Dict[Edge, int] = {}
    for p in range(num_pods):
        sw = n + 1 + p
        for i in range(nodes_per_pod):
            h = p * nodes_per_pod + i
            edges[(h, sw)] = ici_cap
            edges[(sw, h)] = ici_cap
    for h in range(n):
        edges[(h, dcn)] = dcn_cap
        edges[(dcn, h)] = dcn_cap
    return DiGraph(n + 1 + num_pods, frozenset(range(n)), edges,
                   f"multipod[{num_pods}x{nodes_per_pod},{ici_cap}/{dcn_cap}]")


def axis_topology_for_mesh(axis_name: str, axis_size: int) -> DiGraph:
    """Physical topology model for one mesh axis.

    On a 2-D ICI torus laid out as (data, model) = (16, 16), each mesh axis
    maps to torus rings: an axis of size A is a bidirectional ring of A chips
    (2 ICI links each way between neighbours along that axis are available
    to the axis' collectives — we model cap=1 per direction and scale by
    link bandwidth at cost time).  The 'pod' axis crosses DCN: modelled as a
    switch star with 1 unit per pod (skinny), which is where the paper's
    edge splitting matters.
    """
    from .zoo import bidir_ring, star_switch
    if axis_size == 1:
        return DiGraph(1, frozenset({0}), {}, f"{axis_name}-trivial")
    if axis_name == "pod":
        if axis_size == 2:
            # 2 pods: direct bidirectional DCN pipe
            return DiGraph(2, frozenset({0, 1}), {(0, 1): 1, (1, 0): 1},
                           "pod-pipe")
        return star_switch(axis_size, cap=1)
    return bidir_ring(axis_size, cap=1, name=f"{axis_name}-ring{axis_size}")
