"""Declarative topology specs — one parseable front door for every fabric.

A `TopologySpec` names a registered topology *family* plus its parameters
and an optional chain of composable *transforms*, and builds the exact same
`DiGraph` (byte-identical fingerprint) as calling the zoo constructor by
hand:

    TopologySpec.parse("torus2d:8x8").build()          == torus_2d(8, 8)
    TopologySpec.parse("dragonfly:g6,p4").build()      == dragonfly(6, 4)
    TopologySpec.parse("fattree:8p4l2h").build()       == fat_tree(8, 4, 2)
    TopologySpec.parse("hypercube:3@fail(0-1)").build()
                                    == fail_link(hypercube(3), 0, 1)

Grammar (``str(spec)`` prints the canonical form; parse/print round-trips)::

    SPEC       := FAMILY [":" PARAMS] TRANSFORM*
    PARAMS     := [COMPACT] ["," KV]* | KV ["," KV]*
    KV         := name "=" (int | "true" | "false")
    TRANSFORM  := "@" NAME "(" ARG ("-" ARG)* ["," KV]* ")"

Each family may register a COMPACT pattern (``{rows}x{cols}``,
``g{groups},p{per_group}``, ``{pods}p{leaf_per_pod}l{hosts_per_leaf}h``);
parameters not covered by the pattern — and every parameter of a family
without one — are spelled ``name=value``.  Transforms are applied left to
right: ``@fail(0-1)`` removes the bidirectional link 0<->1,
``@degrade(2-3,cap=1)`` reduces 2<->3 to capacity 1 per direction.  The
graph names they produce are the same canonical suffixes, so a degraded
fabric's display name, BENCH row and cache artifact are all self-describing.

Families and transforms self-register via the `register_topology` /
`register_transform` decorators on the zoo builders
(`repro.topo.zoo`, `repro.topo.tpu`); `zoo_specs()` exposes the committed
sweep zoo as named specs, and `resolve_topology()` accepts a `DiGraph`, a
`TopologySpec`, a committed zoo name, or a raw spec string — the form every
`repro.api.Collectives` entry point takes.
"""
from __future__ import annotations

import dataclasses
import inspect
import json
import re
from functools import lru_cache
from typing import (Any, Callable, Dict, FrozenSet, Mapping, Optional,
                    Sequence, Tuple, Union)

from repro.core.graph import DiGraph

SPEC_FORMAT = "repro.topology_spec"

_FAMILY_RE = re.compile(r"^[a-z][a-z0-9_]*$")
_SPEC_RE = re.compile(
    r"^(?P<family>[a-z][a-z0-9_]*)"
    r"(?::(?P<params>[^@]*))?"
    r"(?P<transforms>(?:@[a-z][a-z0-9_]*\([^()]*\))*)$")
_TRANSFORM_RE = re.compile(r"@(?P<name>[a-z][a-z0-9_]*)\((?P<body>[^()]*)\)")
_FIELD_RE = re.compile(r"\{([a-z_][a-z0-9_]*)\}")


class TopologySpecError(ValueError):
    """A spec string / payload that does not parse or does not validate."""


# ---------------------------------------------------------------------- #
# registries
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class TopologyFamily:
    """One registered topology constructor and its spec-grammar metadata."""
    name: str
    fn: Callable[..., DiGraph]
    pattern: Optional[str]                  # compact form, e.g. "{rows}x{cols}"
    param_names: Tuple[str, ...]            # spec-settable builder params
    required: Tuple[str, ...]               # params without a default
    bool_params: FrozenSet[str]             # params whose default is a bool

    @property
    def pattern_fields(self) -> Tuple[str, ...]:
        return tuple(_FIELD_RE.findall(self.pattern)) if self.pattern else ()

    def compact_regex(self) -> Optional[re.Pattern]:
        if not self.pattern:
            return None
        out, pos = [], 0
        for m in _FIELD_RE.finditer(self.pattern):
            out.append(re.escape(self.pattern[pos:m.start()]))
            out.append(f"(?P<{m.group(1)}>\\d+)")
            pos = m.end()
        out.append(re.escape(self.pattern[pos:]))
        return re.compile("^" + "".join(out) + r"(?:,(?P<_extras>.+))?$")


_FAMILIES: Dict[str, TopologyFamily] = {}
_TRANSFORMS: Dict[str, Callable[..., DiGraph]] = {}


def register_topology(name: str, pattern: Optional[str] = None):
    """Class a zoo builder as a spec family: ``@register_topology("torus2d",
    pattern="{rows}x{cols}")``.  Parameters are read off the function
    signature (a ``name=`` display-override parameter is excluded); every
    pattern field must name an int parameter."""
    if not _FAMILY_RE.match(name):
        raise ValueError(f"family name {name!r} must match {_FAMILY_RE.pattern}")

    def deco(fn: Callable[..., DiGraph]) -> Callable[..., DiGraph]:
        sig = inspect.signature(fn)
        params, required, bools = [], [], []
        for p in sig.parameters.values():
            if p.name == "name" or p.kind not in (
                    p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY):
                continue
            params.append(p.name)
            if p.default is inspect.Parameter.empty:
                required.append(p.name)
            elif isinstance(p.default, bool):
                bools.append(p.name)
        entry = TopologyFamily(name=name, fn=fn, pattern=pattern,
                               param_names=tuple(params),
                               required=tuple(required),
                               bool_params=frozenset(bools))
        for f in entry.pattern_fields:
            if f not in entry.param_names:
                raise ValueError(
                    f"family {name!r}: pattern field {f!r} is not a "
                    f"parameter of {fn.__qualname__}")
        prev = _FAMILIES.get(name)
        if prev is not None and prev.fn.__qualname__ != fn.__qualname__:
            raise ValueError(f"topology family {name!r} already registered "
                             f"to {prev.fn.__qualname__}")
        _FAMILIES[name] = entry
        return fn

    return deco


def register_transform(name: str):
    """Register a ``fn(g, *int_args, **int_kwargs) -> DiGraph`` graph
    transform under ``@name(...)`` in the spec grammar."""
    if not _FAMILY_RE.match(name):
        raise ValueError(f"transform name {name!r} must match "
                         f"{_FAMILY_RE.pattern}")

    def deco(fn: Callable[..., DiGraph]) -> Callable[..., DiGraph]:
        prev = _TRANSFORMS.get(name)
        if prev is not None and prev.__qualname__ != fn.__qualname__:
            raise ValueError(f"transform {name!r} already registered to "
                             f"{prev.__qualname__}")
        _TRANSFORMS[name] = fn
        return fn

    return deco


def _ensure_registry() -> None:
    """Importing the zoo modules runs their registration decorators."""
    from repro.topo import tpu, zoo  # noqa: F401  (import side effects)


def topology_families() -> Dict[str, TopologyFamily]:
    """All registered families (name -> entry), zoo included."""
    _ensure_registry()
    return dict(_FAMILIES)


def transform_names() -> Tuple[str, ...]:
    _ensure_registry()
    return tuple(sorted(_TRANSFORMS))


def _family(name: str) -> TopologyFamily:
    _ensure_registry()
    try:
        return _FAMILIES[name]
    except KeyError:
        raise TopologySpecError(
            f"unknown topology family {name!r} (known: "
            f"{', '.join(sorted(_FAMILIES))})") from None


# ---------------------------------------------------------------------- #
# value plumbing
# ---------------------------------------------------------------------- #

def _format_value(v: Any) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    return str(v)


def _parse_value(family: TopologyFamily, key: str, raw: str) -> Any:
    raw = raw.strip()
    if key in family.bool_params:
        if raw in ("true", "1"):
            return True
        if raw in ("false", "0"):
            return False
        raise TopologySpecError(
            f"{family.name}: parameter {key!r} takes true/false, got {raw!r}")
    try:
        return int(raw)
    except ValueError:
        raise TopologySpecError(
            f"{family.name}: parameter {key!r} must be an integer, "
            f"got {raw!r}") from None


def _parse_kv_tokens(family: TopologyFamily, text: str,
                     into: Dict[str, Any]) -> None:
    for tok in text.split(","):
        tok = tok.strip()
        if not tok:
            raise TopologySpecError(
                f"{family.name}: empty parameter token in {text!r}")
        if "=" not in tok:
            raise TopologySpecError(
                f"{family.name}: expected name=value, got {tok!r} "
                f"(compact form: {family.pattern or 'none'})")
        key, raw = tok.split("=", 1)
        key = key.strip()
        if key not in family.param_names:
            raise TopologySpecError(
                f"{family.name}: unknown parameter {key!r} "
                f"(takes {', '.join(family.param_names)})")
        if key in into:
            raise TopologySpecError(
                f"{family.name}: parameter {key!r} given twice")
        into[key] = _parse_value(family, key, raw)


# ---------------------------------------------------------------------- #
# TransformSpec
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class TransformSpec:
    """One graph transform application: ``@name(a-b,key=v)``."""
    name: str
    args: Tuple[int, ...] = ()
    kwargs: Tuple[Tuple[str, int], ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "args", tuple(int(a) for a in self.args))
        kw = self.kwargs.items() if isinstance(self.kwargs, Mapping) \
            else self.kwargs
        object.__setattr__(
            self, "kwargs", tuple(sorted((str(k), int(v)) for k, v in kw)))

    def __str__(self) -> str:
        toks = ["-".join(str(a) for a in self.args)] if self.args else []
        toks += [f"{k}={v}" for k, v in self.kwargs]
        return f"@{self.name}({','.join(toks)})"

    @classmethod
    def parse_text(cls, text: str) -> "TransformSpec":
        """Parse one standalone transform — ``"@fail(0-1)"``,
        ``"@degrade(2-3,cap=1)"`` — the form `Collectives.repair` and the
        launch drivers' ``--inject-fault`` take."""
        m = _TRANSFORM_RE.fullmatch(text.strip())
        if not m:
            raise TopologySpecError(
                f"malformed transform {text!r} (expected '@name(a-b,k=v)')")
        return cls.parse(m.group("name"), m.group("body"))

    @classmethod
    def parse(cls, name: str, body: str) -> "TransformSpec":
        args: Tuple[int, ...] = ()
        kwargs = {}
        for i, tok in enumerate(t.strip() for t in body.split(",") if
                                t.strip()):
            if "=" in tok:
                k, raw = tok.split("=", 1)
                try:
                    kwargs[k.strip()] = int(raw)
                except ValueError:
                    raise TopologySpecError(
                        f"@{name}: {tok!r} is not name=int") from None
            elif i == 0:
                try:
                    args = tuple(int(a) for a in tok.split("-"))
                except ValueError:
                    raise TopologySpecError(
                        f"@{name}: positional args {tok!r} must be "
                        f"'-'-separated integers") from None
            else:
                raise TopologySpecError(
                    f"@{name}: positional token {tok!r} must come first")
        return cls(name=name, args=args, kwargs=tuple(kwargs.items()))

    def apply(self, g: DiGraph) -> DiGraph:
        _ensure_registry()
        try:
            fn = _TRANSFORMS[self.name]
        except KeyError:
            raise TopologySpecError(
                f"unknown transform {self.name!r} (known: "
                f"{', '.join(sorted(_TRANSFORMS))})") from None
        try:
            return fn(g, *self.args, **dict(self.kwargs))
        except TypeError as e:
            raise TopologySpecError(f"{self}: {e}") from None


# ---------------------------------------------------------------------- #
# TopologySpec
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """A declarative, serializable recipe for a topology.

    ``params`` holds only the explicitly-given builder parameters (builder
    defaults fill the rest at `build()` time), normalized to a sorted tuple
    so equal specs compare and hash equal."""
    family: str
    params: Tuple[Tuple[str, Any], ...] = ()
    transforms: Tuple[TransformSpec, ...] = ()

    def __post_init__(self) -> None:
        items = self.params.items() if isinstance(self.params, Mapping) \
            else self.params
        object.__setattr__(
            self, "params", tuple(sorted((str(k), v) for k, v in items)))
        object.__setattr__(self, "transforms", tuple(self.transforms))

    # -------------------------------------------------------------- #
    # parse / print
    # -------------------------------------------------------------- #

    @classmethod
    def parse(cls, text: str) -> "TopologySpec":
        m = _SPEC_RE.match(text.strip())
        if not m:
            raise TopologySpecError(f"malformed topology spec {text!r}")
        family = _family(m.group("family"))
        params: Dict[str, Any] = {}
        body = (m.group("params") or "").strip()
        if m.group("params") is not None and not body:
            raise TopologySpecError(
                f"{family.name}: ':' must be followed by parameters")
        if body:
            compact = family.compact_regex()
            cm = compact.match(body) if compact else None
            if cm:
                extras = cm.groupdict().pop("_extras", None)
                for f in family.pattern_fields:
                    params[f] = int(cm.group(f))
                if extras:
                    _parse_kv_tokens(family, extras, params)
            else:
                _parse_kv_tokens(family, body, params)
        spec = cls(family=family.name, params=tuple(params.items()),
                   transforms=tuple(
                       TransformSpec.parse(t.group("name"), t.group("body"))
                       for t in _TRANSFORM_RE.finditer(
                           m.group("transforms") or "")))
        spec.validate()
        return spec

    def __str__(self) -> str:
        out = self.family
        body = self._params_str()
        if body:
            out += f":{body}"
        return out + "".join(str(t) for t in self.transforms)

    def _params_str(self) -> str:
        params = dict(self.params)
        if not params:
            return ""
        entry = _family(self.family)
        fields = entry.pattern_fields
        toks = []
        if fields and all(f in params for f in fields):
            toks.append(entry.pattern.format(
                **{f: params.pop(f) for f in fields}))
        toks += [f"{k}={_format_value(v)}" for k, v in sorted(params.items())]
        return ",".join(toks)

    # -------------------------------------------------------------- #
    # JSON round-trip
    # -------------------------------------------------------------- #

    def to_dict(self) -> Dict[str, Any]:
        return {
            "format": SPEC_FORMAT,
            "family": self.family,
            "params": dict(self.params),
            "transforms": [{"name": t.name, "args": list(t.args),
                            "kwargs": dict(t.kwargs)}
                           for t in self.transforms],
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "TopologySpec":
        if d.get("format", SPEC_FORMAT) != SPEC_FORMAT:
            raise TopologySpecError(f"not a topology-spec payload: "
                                    f"{d.get('format')!r}")
        try:
            spec = cls(
                family=d["family"],
                params=tuple(dict(d.get("params", {})).items()),
                transforms=tuple(
                    TransformSpec(name=t["name"],
                                  args=tuple(t.get("args", ())),
                                  kwargs=tuple(dict(t.get("kwargs",
                                                          {})).items()))
                    for t in d.get("transforms", ())))
        except (KeyError, TypeError) as e:
            raise TopologySpecError(f"malformed spec payload: {e}") from None
        spec.validate()
        return spec

    @classmethod
    def from_json(cls, text: str) -> "TopologySpec":
        try:
            return cls.from_dict(json.loads(text))
        except json.JSONDecodeError as e:
            raise TopologySpecError(f"spec JSON does not parse: {e}") \
                from None

    # -------------------------------------------------------------- #
    # composition / build
    # -------------------------------------------------------------- #

    def with_transform(self, name: str, *args: int,
                       **kwargs: int) -> "TopologySpec":
        """Append a transform: ``spec.with_transform("degrade", 2, 3,
        cap=1)`` == parsing ``...@degrade(2-3,cap=1)``."""
        t = TransformSpec(name=name, args=args, kwargs=tuple(kwargs.items()))
        return dataclasses.replace(self,
                                   transforms=self.transforms + (t,))

    def fail(self, u: int, v: int) -> "TopologySpec":
        return self.with_transform("fail", u, v)

    def degrade(self, u: int, v: int, cap: int) -> "TopologySpec":
        return self.with_transform("degrade", u, v, cap=cap)

    def validate(self) -> None:
        """Family exists, every param is known, required params present
        whenever any is, and every transform is registered."""
        entry = _family(self.family)
        params = dict(self.params)
        for k in params:
            if k not in entry.param_names:
                raise TopologySpecError(
                    f"{self.family}: unknown parameter {k!r} "
                    f"(takes {', '.join(entry.param_names)})")
        missing = [r for r in entry.required if r not in params]
        if missing:
            raise TopologySpecError(
                f"{self.family}: missing required parameter(s) "
                f"{', '.join(missing)}")
        _ensure_registry()
        for t in self.transforms:
            if t.name not in _TRANSFORMS:
                raise TopologySpecError(f"unknown transform {t.name!r}")

    def build(self) -> DiGraph:
        """Construct the graph — byte-identical (same `fingerprint()`) to
        calling the registered zoo builder with the same parameters."""
        entry = _family(self.family)
        params = dict(self.params)
        missing = [r for r in entry.required if r not in params]
        if missing:
            raise TopologySpecError(
                f"{self.family}: missing required parameter(s) "
                f"{', '.join(missing)}")
        g = entry.fn(**params)
        for t in self.transforms:
            g = t.apply(g)
        return g


# ---------------------------------------------------------------------- #
# zoo table + resolution
# ---------------------------------------------------------------------- #

@lru_cache(maxsize=1)
def _zoo_specs() -> Tuple[Tuple[str, TopologySpec], ...]:
    from repro.topo import zoo
    return tuple((name, TopologySpec.parse(text))
                 for name, text in zoo.ZOO_SPECS.items())


def zoo_specs() -> Dict[str, TopologySpec]:
    """The committed sweep zoo as ``{row_name: TopologySpec}`` — the single
    registry `repro.cache.sweep.sweep_registry()`, BENCH row names and the
    ``--topology`` CLI all derive from."""
    return dict(_zoo_specs())


SpecLike = Union[DiGraph, TopologySpec, str]


def resolve_topology(obj: SpecLike) -> DiGraph:
    """A `DiGraph` passes through; a `TopologySpec` builds; a string is a
    committed zoo name (``"torus8x8_failed"``) or a raw spec
    (``"torus2d:8x8@fail(0-1)"``)."""
    if isinstance(obj, DiGraph):
        return obj
    if isinstance(obj, TopologySpec):
        return obj.build()
    if isinstance(obj, str):
        zoo = zoo_specs()
        if obj in zoo:
            return zoo[obj].build()
        return TopologySpec.parse(obj).build()
    raise TypeError(f"cannot resolve a topology from {type(obj).__name__!r} "
                    f"(takes DiGraph | TopologySpec | spec string)")
