"""Topology zoo — every topology family the paper discusses plus TPU shapes.

All constructors return `DiGraph` with integer capacities.  Compute nodes are
always numbered first (0..N-1), switches after, so compute node ids coincide
with device/rank ids in the runtime.

Every constructor self-registers as a `repro.topo.spec.TopologySpec` family
(the `@register_topology` decorator), and the committed sweep zoo lives here
as the declarative `ZOO_SPECS` table — `sweep_registry()`, BENCH row names,
cache keys and the ``--topology`` CLI all derive from it.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.graph import DiGraph, Edge

from .spec import register_topology, register_transform


# ---------------------------------------------------------------------- #
# direct-connect basics
# ---------------------------------------------------------------------- #

@register_topology("ring", pattern="{n}")
def ring(n: int, cap: int = 1, name: str | None = None) -> DiGraph:
    """Unidirectional ring 0 -> 1 -> ... -> n-1 -> 0."""
    edges = {(i, (i + 1) % n): cap for i in range(n)}
    return DiGraph(n, frozenset(range(n)), edges, name or f"ring{n}")


@register_topology("bring", pattern="{n}")
def bidir_ring(n: int, cap: int = 1, name: str | None = None) -> DiGraph:
    edges: Dict[Edge, int] = {}
    for i in range(n):
        edges[(i, (i + 1) % n)] = cap
        edges[((i + 1) % n, i)] = cap
    return DiGraph(n, frozenset(range(n)), edges, name or f"bring{n}")


@register_topology("line", pattern="{n}")
def line(n: int, cap: int = 1) -> DiGraph:
    """Bidirectional path graph — the pathological non-symmetric case."""
    edges: Dict[Edge, int] = {}
    for i in range(n - 1):
        edges[(i, i + 1)] = cap
        edges[(i + 1, i)] = cap
    return DiGraph(n, frozenset(range(n)), edges, f"line{n}")


@register_topology("full", pattern="{n}")
def fully_connected(n: int, cap: int = 1) -> DiGraph:
    edges = {(i, j): cap for i in range(n) for j in range(n) if i != j}
    return DiGraph(n, frozenset(range(n)), edges, f"full{n}")


@register_topology("torus2d", pattern="{rows}x{cols}")
def torus_2d(rows: int, cols: int, cap: int = 1,
             wrap: bool = True) -> DiGraph:
    """2-D (wrapped) torus — the TPU ICI shape.  Bidirectional links."""
    n = rows * cols

    def nid(r: int, c: int) -> int:
        return (r % rows) * cols + (c % cols)

    edges: Dict[Edge, int] = {}
    for r in range(rows):
        for c in range(cols):
            u = nid(r, c)
            nbrs = []
            if wrap or c + 1 < cols:
                nbrs.append(nid(r, c + 1))
            if wrap or r + 1 < rows:
                nbrs.append(nid(r + 1, c))
            for v in nbrs:
                if u == v:
                    continue
                edges[(u, v)] = edges.get((u, v), 0) + cap
                edges[(v, u)] = edges.get((v, u), 0) + cap
    return DiGraph(n, frozenset(range(n)), edges,
                   f"torus{rows}x{cols}" + ("" if wrap else "-mesh"))


@register_topology("hypercube", pattern="{dim}")
def hypercube(dim: int, cap: int = 1) -> DiGraph:
    """dim-dimensional binary hypercube, bidirectional links."""
    n = 1 << dim
    edges: Dict[Edge, int] = {}
    for u in range(n):
        for b in range(dim):
            v = u ^ (1 << b)
            edges[(u, v)] = cap
    return DiGraph(n, frozenset(range(n)), edges, f"hcube{dim}")


@register_topology("circulant", pattern="n{n},s{lo}-{hi}")
def circulant(n: int, lo: int = 1, hi: int = 4, cap: int = 1) -> DiGraph:
    """Circulant direct-connect C_n(lo..hi): node i links to i ± s (mod n)
    for every stride s in [lo, hi] — the symmetric direct-connect family
    the all-to-all shuffle literature builds its schedules on.  Each stride
    contributes one bidirectional ring, so the graph is vertex-transitive
    and Eulerian.  When a stride satisfies 2s ≡ 0 (mod n) its two
    directions coincide and the shared link accumulates double capacity."""
    if not (1 <= lo <= hi < n):
        raise ValueError(f"need 1 <= lo <= hi < n, got s{lo}-{hi} on n={n}")
    edges: Dict[Edge, int] = {}
    for i in range(n):
        for s in range(lo, hi + 1):
            j = (i + s) % n
            if j == i:
                continue
            edges[(i, j)] = edges.get((i, j), 0) + cap
            edges[(j, i)] = edges.get((j, i), 0) + cap
    return DiGraph(n, frozenset(range(n)), edges, f"circulant{n}s{lo}-{hi}")


@register_topology("torus3d", pattern="{x}x{y}x{z}")
def torus_3d(x: int, y: int, z: int, cap: int = 1) -> DiGraph:
    n = x * y * z

    def nid(i: int, j: int, kk: int) -> int:
        return ((i % x) * y + (j % y)) * z + (kk % z)

    edges: Dict[Edge, int] = {}
    for i in range(x):
        for j in range(y):
            for kk in range(z):
                u = nid(i, j, kk)
                for v in (nid(i + 1, j, kk), nid(i, j + 1, kk),
                          nid(i, j, kk + 1)):
                    if u == v:
                        continue
                    edges[(u, v)] = edges.get((u, v), 0) + cap
                    edges[(v, u)] = edges.get((v, u), 0) + cap
    return DiGraph(n, frozenset(range(n)), edges, f"torus{x}x{y}x{z}")


# ---------------------------------------------------------------------- #
# switch topologies
# ---------------------------------------------------------------------- #

@register_topology("star", pattern="{n}")
def star_switch(n: int, cap: int = 1) -> DiGraph:
    """n compute nodes hanging off one switch (id n)."""
    edges: Dict[Edge, int] = {}
    for i in range(n):
        edges[(i, n)] = cap
        edges[(n, i)] = cap
    return DiGraph(n + 1, frozenset(range(n)), edges, f"star{n}")


@register_topology("two_cluster", pattern="{per_cluster},{local_cap},{global_cap}")
def two_cluster_switch(per_cluster: int = 4, local_cap: int = 10,
                       global_cap: int = 1) -> DiGraph:
    """The paper's Figure 1a: two clusters of `per_cluster` compute nodes,
    one local switch per cluster (local_cap links), one global switch
    (global_cap links per node).  Bottleneck = the cluster cut."""
    n = 2 * per_cluster
    g_sw = n          # global switch v0
    sw1 = n + 1       # cluster-1 switch v1
    sw2 = n + 2       # cluster-2 switch v2
    edges: Dict[Edge, int] = {}
    for i in range(per_cluster):
        edges[(i, sw1)] = local_cap
        edges[(sw1, i)] = local_cap
    for i in range(per_cluster, n):
        edges[(i, sw2)] = local_cap
        edges[(sw2, i)] = local_cap
    for i in range(n):
        edges[(i, g_sw)] = global_cap
        edges[(g_sw, i)] = global_cap
    return DiGraph(n + 3, frozenset(range(n)), edges,
                   f"fig1a[{per_cluster}x2,{local_cap}/{global_cap}]")


@register_topology("fig1a")
def fig1a() -> DiGraph:
    """Paper Figure 1a with b = 1."""
    return two_cluster_switch(4, 10, 1)


@register_topology("fig1d")
def fig1d_ring_unwound() -> DiGraph:
    """Paper Figure 1d: the *suboptimal* TACCL/TACOS-style unwinding of
    Fig 1a into directed rings (each node's switch egress feeds the next
    node's ingress).  Local switches become intra-cluster rings (cap 10),
    the global switch one global ring (cap 1).  The bottleneck cut's egress
    drops from 4b to b — 4x worse (paper §2 discussion)."""
    edges: Dict[Edge, int] = {}
    for base in (0, 4):  # intra-cluster directed rings, cap 10
        for i in range(4):
            u = base + i
            v = base + (i + 1) % 4
            edges[(u, v)] = edges.get((u, v), 0) + 10
    for i in range(8):   # global directed ring, cap 1
        u, v = i, (i + 1) % 8
        edges[(u, v)] = edges.get((u, v), 0) + 1
    return DiGraph(8, frozenset(range(8)), edges, "fig1d-ring-unwound")


@register_topology("fattree", pattern="{pods}p{leaf_per_pod}l{hosts_per_leaf}h")
def fat_tree(pods: int = 4, leaf_per_pod: int = 2, hosts_per_leaf: int = 2,
             host_cap: int = 1, up_cap: int | None = None) -> DiGraph:
    """Two-level fat tree: hosts -> leaf switches -> spine switches.
    TACCL/TACOS cannot handle multi-switch fabrics like this (paper §2);
    edge splitting removes every switch exactly."""
    n_hosts = pods * leaf_per_pod * hosts_per_leaf
    up_cap = up_cap if up_cap is not None else hosts_per_leaf * host_cap
    n_leaf = pods * leaf_per_pod
    spine = n_hosts + n_leaf  # one spine switch (folded core)
    edges: Dict[Edge, int] = {}
    for h in range(n_hosts):
        leaf = n_hosts + h // hosts_per_leaf
        edges[(h, leaf)] = host_cap
        edges[(leaf, h)] = host_cap
    for l in range(n_leaf):
        leaf = n_hosts + l
        edges[(leaf, spine)] = up_cap
        edges[(spine, leaf)] = up_cap
    return DiGraph(n_hosts + n_leaf + 1, frozenset(range(n_hosts)), edges,
                   f"fattree[{pods}p{leaf_per_pod}l{hosts_per_leaf}h]")


@register_topology("dragonfly", pattern="g{groups},p{per_group}")
def dragonfly(groups: int = 3, per_group: int = 2, local_cap: int = 4,
              global_cap: int = 1) -> DiGraph:
    """Dragonfly-lite: per-group router (switch) with all-to-all global links
    between routers; compute nodes hang off their group router."""
    n = groups * per_group
    edges: Dict[Edge, int] = {}
    for g in range(groups):
        router = n + g
        for i in range(per_group):
            h = g * per_group + i
            edges[(h, router)] = local_cap
            edges[(router, h)] = local_cap
    for g1 in range(groups):
        for g2 in range(groups):
            if g1 != g2:
                edges[(n + g1, n + g2)] = global_cap
    return DiGraph(n + groups, frozenset(range(n)), edges,
                   f"dragonfly[{groups}x{per_group}]")


@register_topology("dgx", pattern="{n}")
def dgx_box(n: int = 8, nvlink_cap: int = 12, nic_cap: int = 1) -> DiGraph:
    """A DGX-like box: fully-connected NVLink between n GPUs + a NIC switch
    (models the egress bottleneck when boxes join a fabric)."""
    edges = {(i, j): nvlink_cap for i in range(n) for j in range(n) if i != j}
    sw = n
    for i in range(n):
        edges[(i, sw)] = nic_cap
        edges[(sw, i)] = nic_cap
    return DiGraph(n + 1, frozenset(range(n)), edges, f"dgx{n}")


@register_topology("bcube", pattern="{n}")
def bcube(n: int = 2, cap: int = 1) -> DiGraph:
    """BCube_1(n): n² servers, n level-0 switches (one per pod of n servers)
    and n level-1 switches (one per within-pod index).  Server (p, i) =
    id p·n+i connects to level-0 switch p and level-1 switch i."""
    servers = n * n
    edges: Dict[Edge, int] = {}
    for p in range(n):
        for i in range(n):
            h = p * n + i
            lvl0 = servers + p
            lvl1 = servers + n + i
            for sw in (lvl0, lvl1):
                edges[(h, sw)] = cap
                edges[(sw, h)] = cap
    return DiGraph(servers + 2 * n, frozenset(range(servers)), edges,
                   f"bcube{n}")


@register_topology("meshdgx", pattern="{rows}x{cols}x{gpus}")
def mesh_of_dgx(rows: int = 2, cols: int = 2, gpus: int = 2,
                nvlink_cap: int = 4, dcn_cap: int = 1) -> DiGraph:
    """2-D (non-wrapping) mesh of DGX-style boxes: each box is `gpus`
    NVLink-fully-connected GPUs behind one NIC switch; NIC switches link to
    their mesh neighbours with `dcn_cap` per direction, and every GPU feeds
    its box switch with `dcn_cap`.  All links bidirectional -> Eulerian."""
    boxes = rows * cols
    n = boxes * gpus

    def sw(r: int, c: int) -> int:
        return n + r * cols + c

    edges: Dict[Edge, int] = {}
    for b in range(boxes):
        base = b * gpus
        for i in range(gpus):
            for j in range(gpus):
                if i != j:
                    edges[(base + i, base + j)] = nvlink_cap
            edges[(base + i, n + b)] = dcn_cap
            edges[(n + b, base + i)] = dcn_cap
    for r in range(rows):
        for c in range(cols):
            for (r2, c2) in ((r, c + 1), (r + 1, c)):
                if r2 < rows and c2 < cols:
                    edges[(sw(r, c), sw(r2, c2))] = dcn_cap
                    edges[(sw(r2, c2), sw(r, c))] = dcn_cap
    return DiGraph(n + boxes, frozenset(range(n)), edges,
                   f"meshdgx{rows}x{cols}x{gpus}")


# ---------------------------------------------------------------------- #
# degraded / failed-link variants
# ---------------------------------------------------------------------- #

@register_transform("fail")
def fail_link(g: DiGraph, u: int, v: int, name: str | None = None) -> DiGraph:
    """Remove the bidirectional link u<->v (both directed edges must exist,
    with equal capacity, so the result stays Eulerian)."""
    if g.cap.get((u, v)) != g.cap.get((v, u)) or (u, v) not in g.cap:
        raise ValueError(f"{g.name}: ({u},{v}) is not a symmetric link")
    cap = {e: c for e, c in g.cap.items() if e not in ((u, v), (v, u))}
    out = DiGraph(g.num_nodes, g.compute, cap,
                  name or f"{g.name}@fail({u}-{v})")
    if not out.is_eulerian():
        raise ValueError(f"{g.name}: failing ({u},{v}) breaks Eulerian-ness")
    return out


@register_transform("degrade")
def degrade_link(g: DiGraph, u: int, v: int, cap: int,
                 name: str | None = None) -> DiGraph:
    """Reduce the bidirectional link u<->v to `cap` per direction (models a
    partially failed NVLink/NIC bundle; stays Eulerian by symmetry)."""
    if g.cap.get((u, v)) != g.cap.get((v, u)) or (u, v) not in g.cap:
        raise ValueError(f"{g.name}: ({u},{v}) is not a symmetric link")
    if not (0 < cap < g.cap[(u, v)]):
        raise ValueError(f"degraded capacity {cap} must be in "
                         f"(0, {g.cap[(u, v)]})")
    new = dict(g.cap)
    new[(u, v)] = new[(v, u)] = cap
    return DiGraph(g.num_nodes, g.compute, new,
                   name or f"{g.name}@degrade({u}-{v},cap={cap})")


# ---------------------------------------------------------------------- #
# the committed sweep zoo, declaratively
# ---------------------------------------------------------------------- #

#: Row name -> spec string for every committed sweep/BENCH topology.  This
#: is the ONE hand-maintained table: `repro.topo.spec.zoo_specs()` parses
#: it, `repro.cache.sweep.sweep_registry()` builds from it, BENCH row names
#: are its keys, and degraded/failed variants get their canonical
#: spec-derived display names from the transform suffixes.
ZOO_SPECS: Dict[str, str] = {
    "fig1a": "fig1a",
    "fig1a_degraded": "two_cluster:4,10,2@degrade(0-8,cap=1)",
    "ring8": "ring:8",
    "bring8": "bring:8",
    "bring8_degraded": "bring:8,cap=2@degrade(0-1,cap=1)",
    "line6": "line:6",
    "torus4x4": "torus2d:4x4",
    "torus3x3_failed": "torus2d:3x3@fail(0-1)",
    "hypercube3": "hypercube:3",
    "hypercube3_failed": "hypercube:3@fail(0-1)",
    "bcube2": "bcube:2",
    "bcube3": "bcube:3",
    "meshdgx2x2": "meshdgx:2x2x2",
    "meshdgx2x2_degraded": "meshdgx:2x2x2,dcn_cap=2@degrade(8-9,cap=1)",
    "fattree": "fattree",
    "dragonfly": "dragonfly",
    "dgx8": "dgx:8",
    "star8": "star:8",
    # direct-connect circulants from the all-to-all literature: every node
    # reaches i±s for strides s in the range — dense enough that the
    # per-source scatter trees stay shallow
    "circulant8": "circulant:n8,s1-2",
    "circulant16": "circulant:n16,s1-4",
    "two_cluster_3x6": "two_cluster:3,6,2",
    "multipod": "multipod:2x4",
    # scaled-up rows: the split/pack hot paths dominate even harder here
    # (64 compute nodes, multi-switch fabrics) — these are the rows the
    # warm-started oracle engine is proven on
    "torus8x8": "torus2d:8x8",
    "torus8x8_failed": "torus2d:8x8@fail(0-1)",
    "fattree8p4l2h": "fattree:8p4l2h",
    "fattree8p4l2h_degraded": "fattree:8p4l2h,host_cap=2@degrade(0-64,cap=1)",
    "fattree8p4l4h": "fattree:8p4l4h",
    "dragonfly6x4": "dragonfly:g6,p4",
    "dragonfly6x4_degraded": "dragonfly:g6,p4@degrade(0-24,cap=2)",
    # 256-node fabric: the largest committed row — the compact-CSR maxflow
    # substrate is what makes sweeping this tractable
    "torus16x16": "torus2d:16x16",
}
