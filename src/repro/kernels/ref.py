"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def mha_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  prefix_len: int = 0,
                  logit_cap: Optional[float] = None) -> jax.Array:
    """q: [B,H,Sq,D]; k,v: [B,Hkv,Skv,D] -> [B,H,Sq,D]."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    logits = jnp.einsum("bhgsd,bhtd->bhgst", qg, k.astype(jnp.float32))
    logits = logits / (d ** 0.5)
    if logit_cap is not None:
        logits = logit_cap * jnp.tanh(logits / logit_cap)
    skv = k.shape[2]
    q_pos = jnp.arange(sq)[:, None]
    kv_pos = jnp.arange(skv)[None, :]
    if causal:
        ok = kv_pos <= q_pos
        if window is not None:
            ok &= kv_pos > q_pos - window
        if prefix_len:
            ok |= kv_pos < prefix_len
        logits = jnp.where(ok[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", p, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)


def chunk_accum_reference(acc: jax.Array, update: jax.Array) -> jax.Array:
    """acc: [N, C] f32; update: [N, C] any dtype -> acc + update (f32)."""
    return acc + update.astype(acc.dtype)


def ssd_chunk_reference(x: jax.Array, dt: jax.Array, a: jax.Array,
                        b: jax.Array, c: jax.Array) -> jax.Array:
    """Single-chunk SSD intra-chunk output (no inter-chunk state).
    x: [Q,H,P], dt: [Q,H], a: [H], b,c: [Q,N] -> y [Q,H,P]."""
    q = x.shape[0]
    da = dt * a[None, :]                                  # [Q,H]
    cs = jnp.cumsum(da, axis=0)
    diff = cs[:, None, :] - cs[None, :, :]                # [i,j,H]
    mask = jnp.tril(jnp.ones((q, q), bool))
    l = jnp.where(mask[..., None], jnp.exp(diff), 0.0)    # [i,j,H]
    scores = (c @ b.T)                                    # [i,j]
    xdt = x * dt[..., None]
    return jnp.einsum("ij,ijh,jhp->ihp", scores, l, xdt)
