"""jax version compatibility for the Pallas TPU kernels.

jax has renamed the TPU compiler-params dataclass across releases
(CompilerParams <-> TPUCompilerParams); resolve whichever this install
provides in one place.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
