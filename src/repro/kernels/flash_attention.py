"""Pallas TPU flash attention (GQA, causal/sliding-window/prefix, softcap).

Canonical TPU online-softmax pattern: grid = (B, H, num_q_blocks,
num_kv_blocks) with the kv dimension innermost and marked "arbitrary" so the
VMEM scratch accumulators (m, l, acc) carry across kv steps.  Block sizes
are MXU-aligned (q/kv blocks multiples of 128 on TPU; smaller for tests).

VMEM working set per step:
    q block  [bq, D] + k/v blocks [bk, D]*2 + acc [bq, D] + m/l [bq]
e.g. bq=bk=512, D=128, fp32: ~1.3 MB — well under the ~16 MB/core VMEM.

Validated in interpret=True mode against `ref.mha_reference` over shape and
dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  prefix_len: int, logit_cap: Optional[float],
                  block_q: int, block_kv: int, num_kv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)               # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # [bq, bk]
    if logit_cap is not None:
        logits = logit_cap * jnp.tanh(logits / logit_cap)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    kv_pos = ik * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    if causal:
        ok = kv_pos <= q_pos
        if window is not None:
            ok &= kv_pos > q_pos - window
        if prefix_len:
            ok |= kv_pos < prefix_len
        logits = jnp.where(ok, logits, NEG_INF)

    m_prev = m_ref[...]                               # [bq]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])              # [bq, bk]
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == num_kv - 1)
    def _finish():
        o_ref[0, 0, :, :] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-37)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "prefix_len", "logit_cap",
                              "block_q", "block_kv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    prefix_len: int = 0,
                    logit_cap: Optional[float] = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [B, H, Sq, D]; k, v: [B, Hkv, Skv, D] (H = Hkv * groups).
    Returns [B, H, Sq, D]."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = h // hkv
    bq = min(block_q, sq)
    bk = min(block_kv, skv)
    if sq % bq or skv % bk:
        raise ValueError(f"seq lens ({sq},{skv}) must divide blocks ({bq},{bk})")
    nq, nk = sq // bq, skv // bk
    grid = (b, h, nq, nk)
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        prefix_len=prefix_len, logit_cap=logit_cap,
        block_q=bq, block_kv=bk, num_kv=nk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),               # m
            pltpu.VMEM((bq,), jnp.float32),               # l
            pltpu.VMEM((bq, d), jnp.float32),             # acc
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
