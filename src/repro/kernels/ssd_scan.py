"""Pallas TPU kernel for the Mamba2 SSD intra-chunk block.

The §Perf loop (EXPERIMENTS.md, cell C) showed the chunked SSD's HBM
traffic is dominated by the inter-chunk state and the intra-chunk decay
matrices round-tripping HBM between XLA kernels.  This kernel fuses one
chunk's whole intra-chunk computation in VMEM:

    L[i,j]   = exp(cum[i] - cum[j])   (i >= j, else 0)     [Q, Q]
    y[i]     = sum_j (C[i]·B[j]) * L[i,j] * xdt[j]         [Q, P]
    state    = sum_j exp(cum[Q-1] - cum[j]) * xdt[j] ⊗ B[j]  [P, N]

Grid: (batch*heads, num_chunks); block = one (head, chunk).  VMEM per step:
Q·(P+2N+2) + Q² + P·N floats — Q=256, P=64, N=128: ~0.6 MB.  The decay
matrix L never leaves VMEM, which is exactly the traffic the XLA fallback
pays for.  The inter-chunk recurrence (S/Q steps) stays in XLA — it is
O(S/Q) tiny ops once the intra-chunk work is fused.

Validated in interpret mode against `ref.ssd_chunk_reference`
(tests/test_kernels.py sweeps shapes and dtypes).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from ._compat import CompilerParams as _CompilerParams


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                      y_ref, state_ref, *, chunk: int):
    x = x_ref[0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)        # [Q]
    a = a_ref[0, 0]                           # scalar (this head's A)
    b = b_ref[0].astype(jnp.float32)          # [Q, N]
    c = c_ref[0].astype(jnp.float32)          # [Q, N]

    da = dt * a                               # [Q]
    cum = jnp.cumsum(da)                      # [Q]
    diff = cum[:, None] - cum[None, :]        # [Q, Q]
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ll = jnp.where(iq >= jq, jnp.exp(diff), 0.0)

    xdt = x * dt[:, None]                     # [Q, P]
    scores = jax.lax.dot_general(             # C·B^T  [Q, Q]
        c, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(                  # (scores*L) @ xdt  [Q, P]
        scores * ll, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    decay_state = jnp.exp(cum[-1] - cum)      # [Q]
    state = jax.lax.dot_general(              # xdt^T @ (decay*B)  [P, N]
        xdt, b * decay_state[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_ref[0, 0] = state.astype(state_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_chunk_intra(x: jax.Array, dt: jax.Array, a: jax.Array,
                    b: jax.Array, c: jax.Array, *, chunk: int,
                    interpret: bool = False
                    ) -> Tuple[jax.Array, jax.Array]:
    """Intra-chunk SSD for all (batch, head, chunk) blocks.

    x: [BH, S, P] (batch*heads flattened), dt: [BH, S], a: [BH],
    b, c: [BH, S, N] (per-head replicated upstream).
    Returns (y_diag [BH, S, P], states [BH, S//chunk, P, N])."""
    bh, s, p = x.shape
    n = b.shape[-1]
    if s % chunk:
        raise ValueError(f"seq {s} must divide chunk {chunk}")
    l = s // chunk
    grid = (bh, l)
    return pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, l, p, n), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, dt, a.reshape(bh, 1), b, c)
