"""Jit'd public wrappers for the Pallas kernels.

`interpret` defaults to True on CPU (this container) and False on TPU, so
the same call sites work in tests and production.  `flash_attention_bshd`
adapts the models' [B,S,H,D] layout and registers as the models' flash
implementation via `repro.models.attention.set_flash_impl`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .chunk_accum import chunk_accum as _chunk_accum
from .flash_attention import flash_attention as _flash


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal=True, window=None, prefix_len=0,
                    logit_cap=None, block_q=128, block_kv=128,
                    interpret: Optional[bool] = None):
    """[B,H,S,D] layout."""
    if interpret is None:
        interpret = _on_cpu()
    return _flash(q, k, v, causal=causal, window=window,
                  prefix_len=prefix_len, logit_cap=logit_cap,
                  block_q=block_q, block_kv=block_kv, interpret=interpret)


def chunk_accum(acc, update, *, block_n=8, block_c=512,
                interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _on_cpu()
    return _chunk_accum(acc, update, block_n=block_n, block_c=block_c,
                        interpret=interpret)


def flash_attention_bshd(q, k, v, q_pos, kv_pos, spec, logit_cap):
    """Adapter matching repro.models.attention's flash hook signature:
    q: [B,S,H,D], k/v: [B,T,Hkv,D]; MaskSpec -> kernel flags."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention(
        qt, kt, vt, causal=spec.causal, window=spec.window,
        prefix_len=spec.prefix_len, logit_cap=logit_cap)
    return jnp.swapaxes(out, 1, 2)


def enable_flash_in_models() -> None:
    from repro.models.attention import set_flash_impl
    set_flash_impl(flash_attention_bshd)


def disable_flash_in_models() -> None:
    from repro.models.attention import set_flash_impl
    set_flash_impl(None)
