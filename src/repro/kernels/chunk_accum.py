"""Pallas chunk-accumulate: the reduce-scatter arithmetic hot spot.

Every pipeline round of the paper's reduce-scatter schedule lands incoming
partial-sum chunks that must be added into the local fp32 accumulator:

    acc[slot] += incoming.astype(f32)

Off the shelf this is a bf16->f32 upcast + add + writeback through HBM per
round.  The kernel tiles both operands into VMEM ([block_n, block_c] tiles,
lane-aligned multiples of 128) and fuses upcast+add in-register, so the
accumulator row is read and written exactly once per round.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from ._compat import CompilerParams as _CompilerParams


def _accum_kernel(acc_ref, upd_ref, out_ref):
    out_ref[...] = acc_ref[...] + upd_ref[...].astype(acc_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_c", "interpret"))
def chunk_accum(acc: jax.Array, update: jax.Array, *,
                block_n: int = 8, block_c: int = 512,
                interpret: bool = False) -> jax.Array:
    """acc: [N, C] float32; update: [N, C] (bf16/f16/f32) -> acc + update."""
    n, c = acc.shape
    bn = min(block_n, n)
    bc = min(block_c, c)
    if n % bn or c % bc:
        raise ValueError(f"shape ({n},{c}) must divide blocks ({bn},{bc})")
    grid = (n // bn, c // bc)
    return pl.pallas_call(
        _accum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bc), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bc), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bn, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, c), acc.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(acc, update)
