# Pallas TPU kernels for the perf-critical compute layers, each with a
# pure-jnp oracle in ref.py and interpret=True validation in tests.
from .ops import (chunk_accum, flash_attention,  # noqa: F401
                  flash_attention_bshd, enable_flash_in_models,
                  disable_flash_in_models)
from .ssd_scan import ssd_chunk_intra  # noqa: F401
