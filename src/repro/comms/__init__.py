from .executor import (PermuteCall, PermuteProgram,  # noqa: F401
                       compile_program, programs_for_topology,
                       schedules_for_topology)
from .collectives import (tree_all_gather, tree_all_reduce,  # noqa: F401
                          tree_all_to_all, tree_broadcast, tree_reduce,
                          tree_reduce_scatter)
from .mesh_axes import CollectiveContext, AxisSchedules  # noqa: F401
from .overlap import BucketedAllReduce, compressed_all_reduce  # noqa: F401
