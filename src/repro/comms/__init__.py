from .executor import PermuteCall, PermuteProgram, compile_program  # noqa: F401
from .collectives import (tree_all_gather, tree_reduce_scatter,  # noqa: F401
                          tree_all_reduce)
from .mesh_axes import CollectiveContext, AxisSchedules  # noqa: F401
from .overlap import BucketedAllReduce, compressed_all_reduce  # noqa: F401
