"""Compile a `PipelineSchedule` into a static CollectivePermute program.

On TPU the native point-to-point collective is CollectivePermute
(`jax.lax.ppermute`): one call moves, for every (src, dst) pair in a partial
permutation, the src's operand buffer to dst.  A pipeline round — a set of
simultaneous chunk transfers — therefore becomes one or more ppermute calls:

* sends in a round are grouped by (src, dst) and laid out in slot order;
* each "layer" (i-th chunk of every pair) is decomposed into partial
  permutations (JAX requires unique sources AND destinations per call; tree
  fan-out of degree d costs d calls — same bytes, the per-link load already
  accounts for it);
* calls with identical permutations across consecutive layers are merged
  into one width-w call moving a [w, chunk] stacked payload (this collapses
  the m parallel trees of a multiplicity-m class into a single call).

The result is a `PermuteProgram`: a static, SPMD-safe artifact.  Every
device executes the same call sequence; per-device behaviour is driven by
gather/scatter index tables indexed with `lax.axis_index` inside shard_map.
Slot index `num_slots` is a trash row: devices that do not receive in a call
scatter the (zero) ppermute result there.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import DiGraph
from repro.core.schedule import PipelineSchedule, Send


@dataclasses.dataclass(frozen=True)
class PermuteCall:
    """One ppermute: a partial permutation moving `width` stacked chunks."""
    perm: Tuple[Tuple[int, int], ...]           # (src, dst) pairs
    send_slots: np.ndarray                      # [axis_size, width] int32
    recv_slots: np.ndarray                      # [axis_size, width] int32
    width: int


@dataclasses.dataclass(frozen=True)
class PermuteProgram:
    kind: str
    axis_size: int                 # number of devices in the group
    num_slots: int                 # N * slots_per_shard (+1 trash row extra)
    slots_per_shard: int           # k * P
    rounds: Tuple[Tuple[PermuteCall, ...], ...]
    root: Optional[int] = None     # single root (broadcast/reduce kinds)

    @property
    def num_calls(self) -> int:
        return sum(len(r) for r in self.rounds)

    def describe(self) -> str:
        return (f"PermuteProgram[{self.kind}] A={self.axis_size} "
                f"S={self.slots_per_shard} rounds={len(self.rounds)} "
                f"calls={self.num_calls}")


def _slot_of(send: Send, slots_per_shard: int) -> int:
    return send.root * slots_per_shard + send.slot


def compile_program(sched: PipelineSchedule) -> PermuteProgram:
    """Lower a pipeline schedule to ppermute calls (device ids = compute
    node ids, which the topology constructors number 0..A-1).

    This is stage 5 ("lower") of the staged compiler pipeline: its wall
    time is recorded into the schedule's `compile_stats` (replacing any
    earlier lower record, so repeated lowering stays idempotent)."""
    t0 = time.perf_counter()
    a = sched.num_nodes
    s = sched.slots_per_shard
    if sorted(sched.dstar.compute) != list(range(a)):
        raise ValueError("compute node ids must be 0..A-1 for execution")
    trash = a * s
    rounds: List[Tuple[PermuteCall, ...]] = []
    for rnd in sched.rounds:
        # (src, dst) -> ordered slot list
        pair_slots: Dict[Tuple[int, int], List[int]] = {}
        for send in sorted(rnd, key=lambda x: (x.cls, x.slot)):
            pair_slots.setdefault((send.src, send.dst), []).append(
                _slot_of(send, s))
        # layer l = l-th slot of each pair; then partial-permutation split
        max_layers = max(len(v) for v in pair_slots.values())
        raw_calls: List[Dict[Tuple[int, int], int]] = []
        for layer in range(max_layers):
            todo = {p: sl[layer] for p, sl in pair_slots.items()
                    if len(sl) > layer}
            while todo:
                call: Dict[Tuple[int, int], int] = {}
                used_src, used_dst = set(), set()
                for (src, dst), slot in sorted(todo.items()):
                    if src in used_src or dst in used_dst:
                        continue
                    call[(src, dst)] = slot
                    used_src.add(src)
                    used_dst.add(dst)
                for p in call:
                    del todo[p]
                raw_calls.append(call)
        # merge consecutive calls with identical perms into width-w calls
        merged: List[List[Dict[Tuple[int, int], int]]] = []
        for call in raw_calls:
            if merged and set(merged[-1][0]) == set(call):
                merged[-1].append(call)
            else:
                merged.append([call])
        calls: List[PermuteCall] = []
        for group in merged:
            w = len(group)
            perm = tuple(sorted(group[0]))
            send_slots = np.zeros((a, w), dtype=np.int32)
            recv_slots = np.full((a, w), trash, dtype=np.int32)
            for j, call in enumerate(group):
                for (src, dst), slot in call.items():
                    send_slots[src, j] = slot
                    recv_slots[dst, j] = slot
            calls.append(PermuteCall(perm=perm, send_slots=send_slots,
                                     recv_slots=recv_slots, width=w))
        rounds.append(tuple(calls))
    prog = PermuteProgram(kind=sched.kind, axis_size=a,
                          num_slots=a * s, slots_per_shard=s,
                          rounds=tuple(rounds), root=sched.root)
    stats = getattr(sched, "compile_stats", None)
    if stats is not None:
        sched.compile_stats = stats.with_stage(
            "lower", time.perf_counter() - t0,
            calls=prog.num_calls, rounds=len(prog.rounds))
    return prog


# ---------------------------------------------------------------------- #
# cache-aware schedule acquisition — DEPRECATED shims over repro.api
# ---------------------------------------------------------------------- #

def schedules_for_topology(topo: DiGraph, num_chunks: int = 8,
                           fixed_k: Optional[int] = None, cache=None,
                           kind: Optional[str] = None,
                           root: Optional[int] = None):
    """DEPRECATED — use `repro.api.Collectives.schedule` / `.pair`.

    Kept as an externally-compatible shim: ``kind=None`` returns the
    (allgather, reduce_scatter) pair compiled as one family, any other kind
    one artifact, exactly as before — but the work is delegated to the
    `Collectives` facade and a `ReproDeprecationWarning` is raised (tier-1
    promotes it to an error for in-repo callers)."""
    from repro.api import Collectives, warn_deprecated
    warn_deprecated("repro.comms.schedules_for_topology",
                    "repro.api.Collectives.schedule (or .pair/.family)")
    coll = Collectives(cache=cache, num_chunks=num_chunks, fixed_k=fixed_k)
    if kind is None:
        return coll.pair(topo)
    if kind in ("broadcast", "reduce") and root is None:
        raise ValueError(f"{kind} schedules need an explicit root")
    if kind not in ("allgather", "reduce_scatter", "broadcast", "reduce",
                    "allreduce"):
        raise ValueError(f"unknown collective kind {kind!r}")
    return coll.schedule(topo, kind=kind, root=root,
                         fixed_k=None if kind in ("broadcast", "reduce")
                         else fixed_k)


def programs_for_topology(topo: DiGraph, num_chunks: int = 8,
                          fixed_k: Optional[int] = None, cache=None
                          ) -> Tuple[PermuteProgram, PermuteProgram]:
    """DEPRECATED — use `repro.api.Collectives.program(kind="allreduce")`,
    which returns the same (rs_prog, ag_prog) pair `tree_all_reduce`
    expects."""
    from repro.api import Collectives, warn_deprecated
    warn_deprecated("repro.comms.programs_for_topology",
                    'repro.api.Collectives.program(kind="allreduce")')
    coll = Collectives(cache=cache, num_chunks=num_chunks, fixed_k=fixed_k)
    ag, rs = coll.pair(topo)
    return compile_program(rs), compile_program(ag)

