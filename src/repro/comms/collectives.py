"""Tree-pipeline collectives executed with `lax.ppermute` under shard_map.

These are drop-in replacements for `lax.all_gather` / `psum_scatter` / `psum`
whose communication pattern is the paper's bandwidth-optimal pipeline
schedule instead of XLA's built-in algorithm.  They must be called INSIDE a
`shard_map` over the mesh axis the program was compiled for.

Data layout: the per-device shard is flattened and padded to
`slots_per_shard` equal chunks; the working buffer is
[axis_size * slots_per_shard + 1, chunk_elems] (last row = trash for
non-receivers).  Each `PermuteCall` is 3 ops: gather chunk(s), ppermute,
scatter (set for allgather, add for reduce-scatter).

On TPU the scatter-add of reduce-scatter is the arithmetic hot spot; the
Pallas `chunk_accum` kernel (src/repro/kernels) fuses it in VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .executor import PermuteCall, PermuteProgram


def _me(axis_name: str) -> jax.Array:
    return jax.lax.axis_index(axis_name)


def _run_call(buf: jax.Array, call: PermuteCall, axis_name: str,
              me: jax.Array, mode: str) -> jax.Array:
    send_idx = jnp.asarray(call.send_slots)[me]      # [width]
    recv_idx = jnp.asarray(call.recv_slots)[me]      # [width]
    payload = jnp.take(buf, send_idx, axis=0)        # [width, chunk]
    got = jax.lax.ppermute(payload, axis_name, list(call.perm))
    if mode == "set":
        # non-receivers target the trash row; receivers get exactly one write
        return buf.at[recv_idx].set(got, mode="promise_in_bounds")
    # reduce-scatter: accumulate the incoming partial into our partial
    return buf.at[recv_idx].add(got, mode="promise_in_bounds")


def _run_program(buf: jax.Array, prog: PermuteProgram, axis_name: str,
                 mode: str) -> jax.Array:
    me = _me(axis_name)
    for rnd in prog.rounds:
        for call in rnd:
            buf = _run_call(buf, call, axis_name, me, mode)
    return buf


def _chunk_elems(shard_elems: int, slots: int) -> int:
    return -(-shard_elems // slots)  # ceil


# ---------------------------------------------------------------------- #
# allgather
# ---------------------------------------------------------------------- #

def tree_all_gather(x: jax.Array, prog: PermuteProgram, axis_name: str,
                    *, tiled: bool = False) -> jax.Array:
    """Bandwidth-optimal pipelined allgather of the local shard `x`.

    Returns [A, *x.shape] (or concatenated along axis 0 when tiled=True),
    matching `lax.all_gather` semantics."""
    if prog.kind != "allgather":
        raise ValueError(f"program kind {prog.kind} != allgather")
    a, s = prog.axis_size, prog.slots_per_shard
    shard_elems = int(np.prod(x.shape)) if x.ndim else 1
    ce = _chunk_elems(shard_elems, s)
    flat = jnp.ravel(x)
    flat = jnp.pad(flat, (0, s * ce - shard_elems))
    me = _me(axis_name)
    buf = jnp.zeros((a * s + 1, ce), dtype=x.dtype)
    buf = jax.lax.dynamic_update_slice_in_dim(
        buf, flat.reshape(s, ce), me * s, axis=0)
    buf = _run_program(buf, prog, axis_name, mode="set")
    out = buf[:a * s].reshape(a, s * ce)[:, :shard_elems]
    out = out.reshape((a,) + x.shape)
    if tiled:
        out = out.reshape((a * x.shape[0],) + x.shape[1:]) if x.ndim else out
    return out


# ---------------------------------------------------------------------- #
# reduce-scatter
# ---------------------------------------------------------------------- #

def tree_reduce_scatter(x: jax.Array, prog: PermuteProgram, axis_name: str,
                        *, accum_dtype: Optional[jnp.dtype] = None
                        ) -> jax.Array:
    """Bandwidth-optimal pipelined reduce-scatter.

    `x` has leading dim A*<shard>; returns this device's reduced shard
    (shape [shard, ...]), matching `lax.psum_scatter(tiled=True)`."""
    if prog.kind != "reduce_scatter":
        raise ValueError(f"program kind {prog.kind} != reduce_scatter")
    a, s = prog.axis_size, prog.slots_per_shard
    if x.shape[0] % a:
        raise ValueError(f"leading dim {x.shape[0]} not divisible by {a}")
    shard_rows = x.shape[0] // a
    shard_shape = (shard_rows,) + x.shape[1:]
    shard_elems = int(np.prod(shard_shape))
    ce = _chunk_elems(shard_elems, s)
    compute_dtype = accum_dtype or (
        jnp.float32 if x.dtype in (jnp.bfloat16, jnp.float16) else x.dtype)
    flat = x.reshape(a, shard_elems).astype(compute_dtype)
    flat = jnp.pad(flat, ((0, 0), (0, s * ce - shard_elems)))
    buf = jnp.concatenate(
        [flat.reshape(a * s, ce),
         jnp.zeros((1, ce), dtype=compute_dtype)], axis=0)
    buf = _run_program(buf, prog, axis_name, mode="add")
    me = _me(axis_name)
    mine = jax.lax.dynamic_slice_in_dim(buf, me * s, s, axis=0)
    out = mine.reshape(s * ce)[:shard_elems].reshape(shard_shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- #
# alltoall (per-source pruned scatter over the packed spanning trees)
# ---------------------------------------------------------------------- #

def tree_all_to_all(x: jax.Array, prog: PermuteProgram, axis_name: str
                    ) -> jax.Array:
    """Bandwidth-optimal pipelined all-to-all of the destination blocks `x`.

    `x` is [A, *block]: ``x[w]`` is this device's block for destination
    ``w``.  Returns [A, *block] with ``out[r]`` = source r's block for this
    device, matching ``jax.lax.all_to_all(x, axis_name, 0, 0)``.

    Alltoall programs fold the destination into the slot index
    (slots_per_shard = A·k·P; slot = dest·k·P + subslot), so each source's
    whole send buffer is staged contiguously at rows [me·S, (me+1)·S) in
    destination-major order.  The diagonal block is never on the wire (the
    schedule prunes it); it stays where this device staged it, and the
    gather below reads it back from our own rows.  Transit chunks a device
    forwards for others land at rows whose dest index differs from ours,
    so they never clobber an output row."""
    if prog.kind != "alltoall":
        raise ValueError(f"program kind {prog.kind} != alltoall")
    a, s = prog.axis_size, prog.slots_per_shard
    if x.shape[0] != a:
        raise ValueError(f"leading dim {x.shape[0]} != axis size {a}")
    kp = s // a                       # subslots per destination block (k·P)
    block_shape = x.shape[1:]
    block_elems = int(np.prod(block_shape)) if len(block_shape) else 1
    ce = _chunk_elems(block_elems, kp)
    me = _me(axis_name)
    flat = x.reshape(a, block_elems)
    flat = jnp.pad(flat, ((0, 0), (0, kp * ce - block_elems)))
    buf = jnp.zeros((a * s + 1, ce), dtype=x.dtype)
    buf = jax.lax.dynamic_update_slice_in_dim(
        buf, flat.reshape(s, ce), me * s, axis=0)
    buf = _run_program(buf, prog, axis_name, mode="set")
    # source r's block for us sits at rows r*S + me*kp + t
    rows = (jnp.arange(a) * s)[:, None] + me * kp + jnp.arange(kp)[None, :]
    out = jnp.take(buf, rows.reshape(-1), axis=0)
    out = out.reshape(a, kp * ce)[:, :block_elems]
    return out.reshape((a,) + block_shape)


# ---------------------------------------------------------------------- #
# broadcast / reduce (paper Appendix A and its edge-reversed dual)
# ---------------------------------------------------------------------- #

def tree_broadcast(x: jax.Array, prog: PermuteProgram, axis_name: str
                   ) -> jax.Array:
    """Bandwidth-optimal pipelined broadcast of the root's buffer `x`.

    Every device passes an `x` of the same shape (non-root values are
    ignored, matching MPI_Bcast); every device returns the root's `x`.
    The schedule's store-and-forward discipline guarantees non-root data
    never propagates: a device only ever sends chunks it received."""
    if prog.kind != "broadcast":
        raise ValueError(f"program kind {prog.kind} != broadcast")
    a, s = prog.axis_size, prog.slots_per_shard
    root = prog.root
    shard_elems = int(np.prod(x.shape)) if x.ndim else 1
    ce = _chunk_elems(shard_elems, s)
    flat = jnp.ravel(x)
    flat = jnp.pad(flat, (0, s * ce - shard_elems))
    buf = jnp.zeros((a * s + 1, ce), dtype=x.dtype)
    # slot layout matches the executor: the root's chunks live at
    # [root*s, (root+1)*s); every device stages its own copy there (only the
    # root's is ever forwarded)
    buf = jax.lax.dynamic_update_slice_in_dim(
        buf, flat.reshape(s, ce), root * s, axis=0)
    buf = _run_program(buf, prog, axis_name, mode="set")
    out = jax.lax.dynamic_slice_in_dim(buf, root * s, s, axis=0)
    return out.reshape(s * ce)[:shard_elems].reshape(x.shape)


def tree_reduce(x: jax.Array, prog: PermuteProgram, axis_name: str,
                *, accum_dtype: Optional[jnp.dtype] = None) -> jax.Array:
    """Bandwidth-optimal pipelined reduce (sum) of `x` to the root.

    Every device contributes its `x`; the return value equals Σ_devices x on
    the root device and an intermediate partial elsewhere (MPI_Reduce
    semantics).  Accumulation happens at every tree hop (op fusion): each
    device forwards one partial per chunk slot, never raw operands."""
    if prog.kind != "reduce":
        raise ValueError(f"program kind {prog.kind} != reduce")
    a, s = prog.axis_size, prog.slots_per_shard
    root = prog.root
    shard_elems = int(np.prod(x.shape)) if x.ndim else 1
    ce = _chunk_elems(shard_elems, s)
    compute_dtype = accum_dtype or (
        jnp.float32 if x.dtype in (jnp.bfloat16, jnp.float16) else x.dtype)
    flat = jnp.ravel(x).astype(compute_dtype)
    flat = jnp.pad(flat, (0, s * ce - shard_elems))
    buf = jnp.zeros((a * s + 1, ce), dtype=compute_dtype)
    buf = jax.lax.dynamic_update_slice_in_dim(
        buf, flat.reshape(s, ce), root * s, axis=0)
    buf = _run_program(buf, prog, axis_name, mode="add")
    out = jax.lax.dynamic_slice_in_dim(buf, root * s, s, axis=0)
    return out.reshape(s * ce)[:shard_elems].reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------- #
# allreduce = RS + AG (paper Appendix B)
# ---------------------------------------------------------------------- #

def tree_all_reduce(x: jax.Array, rs_prog: PermuteProgram,
                    ag_prog: PermuteProgram, axis_name: str,
                    *, accum_dtype: Optional[jnp.dtype] = None) -> jax.Array:
    """Bandwidth-optimal allreduce: reduce-scatter then allgather.
    Matches `lax.psum` semantics for arbitrary-shaped x."""
    a = rs_prog.axis_size
    orig_shape = x.shape
    elems = int(np.prod(orig_shape)) if x.ndim else 1
    pad = (-elems) % a
    flat = jnp.ravel(x)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    flat = flat.reshape(a, (elems + pad) // a)
    shard = tree_reduce_scatter(flat, rs_prog, axis_name,
                                accum_dtype=accum_dtype)
    full = tree_all_gather(shard, ag_prog, axis_name)
    out = full.reshape(-1)[:elems]
    return out.reshape(orig_shape)


# ---------------------------------------------------------------------- #
# multi-axis composition (hierarchical: RS in, AG out)
# ---------------------------------------------------------------------- #

def tree_all_reduce_multi(x: jax.Array, progs: Sequence[tuple],
                          *, accum_dtype: Optional[jnp.dtype] = None
                          ) -> jax.Array:
    """Allreduce over several mesh axes: reduce-scatter innermost-out, then
    allgather in reverse — the standard hierarchical composition, with each
    stage's schedule bandwidth-optimal for its own axis topology.

    progs: sequence of (axis_name, rs_prog, ag_prog)."""
    if not progs:
        return x
    (axis, rs_p, ag_p), *rest = progs
    a = rs_p.axis_size
    orig_shape = x.shape
    elems = int(np.prod(orig_shape)) if x.ndim else 1
    pad = (-elems) % a
    flat = jnp.ravel(x)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    flat = flat.reshape(a, (elems + pad) // a)
    shard = tree_reduce_scatter(flat, rs_p, axis,
                                accum_dtype=accum_dtype)
    shard = tree_all_reduce_multi(shard, rest, accum_dtype=accum_dtype)
    full = tree_all_gather(shard, ag_p, axis)
    out = full.reshape(-1)[:elems]
    return out.reshape(orig_shape)
