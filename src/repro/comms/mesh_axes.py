"""Per-mesh-axis schedule compilation and caching.

Production collectives on a TPU mesh decompose axis-wise (an allreduce over
('pod','data') = hierarchical RS/AG per axis).  Each axis has a *physical*
topology model (torus ring for ICI axes, switch star / pipe for the DCN
'pod' axis) and gets its own bandwidth-optimal schedule through the
`repro.api.Collectives` facade.  Programs are cached per (axis, kind, P) in
memory; attach an on-disk `repro.cache.ScheduleCache` (or pass a facade
that owns one) to also skip compilation across processes/launches.

Axis topology overrides accept every `Collectives` topology form: a
`DiGraph`, a `TopologySpec`, a zoo row name, or a raw spec string —
``CollectiveContext({'data': 8}, topologies={'data': 'bring:8'})``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from repro.api import Collectives
from repro.core.graph import DiGraph
from repro.core.schedule import PipelineSchedule
from repro.topo.spec import SpecLike, resolve_topology
from repro.topo.tpu import axis_topology_for_mesh
from .executor import PermuteProgram


@dataclasses.dataclass
class AxisSchedules:
    axis_name: str
    topology: DiGraph
    ag_sched: PipelineSchedule
    rs_sched: PipelineSchedule
    ag_prog: PermuteProgram
    rs_prog: PermuteProgram


class CollectiveContext:
    """Holds compiled tree-pipeline programs for every axis of a mesh.

    mesh_axes: {axis_name: size}.  Topologies default to the TPU model
    (`axis_topology_for_mesh`) but can be overridden per axis with any
    spec form — this is the knob the perf loop turns (ring vs torus-line
    vs custom DCN model).  All schedule acquisition goes through one
    `repro.api.Collectives` facade: pass ``collectives=`` to share a
    configured facade, or the legacy ``schedule_cache=`` /
    ``num_chunks`` / ``fixed_k`` knobs to have the context build one.
    """

    def __init__(self, mesh_axes: Dict[str, int],
                 num_chunks: Optional[int] = None,
                 topologies: Optional[Dict[str, SpecLike]] = None,
                 fixed_k: Optional[int] = None,
                 schedule_cache=None,
                 collectives: Optional[Collectives] = None):
        self.mesh_axes = dict(mesh_axes)
        if collectives is None:
            collectives = Collectives(
                cache=schedule_cache,
                num_chunks=num_chunks if num_chunks is not None else 8,
                fixed_k=fixed_k)
        elif (schedule_cache is not None or num_chunks is not None
              or fixed_k is not None):
            raise TypeError("pass either collectives= or the legacy "
                            "schedule_cache=/num_chunks=/fixed_k= knobs, "
                            "not both — the facade already carries them")
        self.collectives = collectives
        self.num_chunks = collectives.options.num_chunks
        self.fixed_k = collectives.options.fixed_k
        self._topologies: Dict[str, DiGraph] = {
            axis: resolve_topology(t)
            for axis, t in (topologies or {}).items()}
        self._cache: Dict[str, AxisSchedules] = {}
        self._allreduce: Dict[str, object] = {}
        self._broadcast: Dict[Tuple[str, int], PermuteProgram] = {}
        self._broadcast_scheds: Dict[Tuple[str, int], PipelineSchedule] = {}
        self._alltoall: Dict[str, PermuteProgram] = {}
        self._alltoall_scheds: Dict[str, PipelineSchedule] = {}

    @property
    def schedule_cache(self):
        """The facade's attached `ScheduleCache` (None when uncached)."""
        return self.collectives.cache

    def topology(self, axis: str) -> DiGraph:
        if axis not in self._topologies:
            self._topologies[axis] = axis_topology_for_mesh(
                axis, self.mesh_axes[axis])
        return self._topologies[axis]

    def axis(self, axis: str) -> AxisSchedules:
        """AG + RS schedules and programs for one axis, compiled as a
        single family through the facade: the §2.1 solve and the
        split/pack products are shared between the two orientations
        instead of being recomputed per kind."""
        if axis not in self._cache:
            topo = self.topology(axis)
            ag, rs = self.collectives.pair(topo)
            ag_prog, rs_prog = (self.collectives.lower(ag),
                                self.collectives.lower(rs))
            self._cache[axis] = AxisSchedules(
                axis_name=axis, topology=topo,
                ag_sched=ag, rs_sched=rs,
                ag_prog=ag_prog, rs_prog=rs_prog)
        return self._cache[axis]

    def allreduce_schedule(self, axis: str):
        """The composed RS+AG `AllReduceSchedule` for one axis, fetched (or
        compiled into) the schedule cache as a single `repro.allreduce`
        artifact — the entry `BucketedAllReduce` consumers replay."""
        if axis not in self._allreduce:
            self._allreduce[axis] = self.collectives.schedule(
                self.topology(axis), kind="allreduce")
        return self._allreduce[axis]

    def bucketed_allreduce(self, axis: str, bucket_bytes: int = 64 << 20,
                           **kwargs):
        """A `BucketedAllReduce` gradient hook for `axis`, lowered from the
        axis's single cached allreduce artifact.  `wire_dtype` (and any
        other `BucketedAllReduce.from_schedule` option) passes through, so
        the bf16 wire-compression default is the same on both construction
        paths."""
        from .overlap import BucketedAllReduce
        return BucketedAllReduce.from_schedule(
            self.allreduce_schedule(axis), axis_name=axis,
            bucket_bytes=bucket_bytes, **kwargs)

    def broadcast_program(self, axis: str, root: int = 0) -> PermuteProgram:
        """Executable single-root broadcast program for `axis` (parameter /
        checkpoint distribution), cache-backed like every other kind and
        memoized per (axis, root)."""
        key = (axis, root)
        if key not in self._broadcast:
            sched = self.collectives.schedule(
                self.topology(axis), kind="broadcast", root=root)
            self._broadcast_scheds[key] = sched
            self._broadcast[key] = self.collectives.lower(sched)
        return self._broadcast[key]

    def alltoall_program(self, axis: str) -> PermuteProgram:
        """Executable all-to-all program for `axis` (expert dispatch /
        sharded transpose), cache-backed like every other kind and memoized
        per axis.  Compiled at P = 1: each spanning tree already pipelines
        A−1 destination blocks back-to-back, so sub-chunking only multiplies
        ppermute calls without shortening the pipeline."""
        if axis not in self._alltoall:
            sched = self.collectives.schedule(
                self.topology(axis), kind="alltoall", num_chunks=1)
            self._alltoall_scheds[axis] = sched
            self._alltoall[axis] = self.collectives.lower(sched)
        return self._alltoall[axis]

    def allreduce_programs(self, axes: Sequence[str]
                           ) -> Tuple[Tuple[str, PermuteProgram,
                                            PermuteProgram], ...]:
        """(axis, rs_prog, ag_prog) tuples for tree_all_reduce_multi,
        ordered with the largest (cheapest-per-byte) axis first so the
        skinny DCN axis reduces the least data."""
        order = sorted((a for a in axes if self.mesh_axes[a] > 1),
                       key=lambda a: -self.mesh_axes[a])
        return tuple((a, self.axis(a).rs_prog, self.axis(a).ag_prog)
                     for a in order)

    def hot_swap(self, transform, axes: Optional[Sequence[str]] = None
                 ) -> Dict[str, list]:
        """Repair every compiled schedule of the axes a fabric transform
        touches, and atomically swap the repaired programs in.

        ``transform`` is a `repro.topo.spec.TransformSpec` or its text form
        (``"@fail(0-1)"``, ``"@degrade(2-3,cap=1)"``); axes whose topology
        does not carry the named link are left untouched.  Every memoized
        artifact of an affected axis (AG/RS pair, allreduce, broadcasts) is
        delta-recompiled through `Collectives.repair` — byte-identical to a
        cold compile of the degraded topology and re-verified on it — and
        the axis topology is updated so later compiles see the degraded
        fabric.  All repairs are staged off to the side first and committed
        in one pass at the end, so a failing repair (e.g. a fault that
        disconnects an axis) raises without leaving the context half-
        swapped.  An affected axis holding a compiled alltoall program
        raises `RepairError` up front (repair does not support alltoall).
        Returns ``{axis: [RepairReport, ...]}``.
        """
        from repro.topo.spec import TransformSpec
        spec = (transform if isinstance(transform, TransformSpec)
                else TransformSpec.parse_text(transform))
        if len(spec.args) < 2:
            raise ValueError(f"{spec} names no link; hot_swap repairs "
                             f"link-level faults")
        u, v = spec.args[0], spec.args[1]
        scope = (list(axes) if axes is not None
                 else [a for a, s in self.mesh_axes.items() if s > 1])
        reports: Dict[str, list] = {}
        staged_topo: Dict[str, DiGraph] = {}
        staged_axis: Dict[str, AxisSchedules] = {}
        staged_ar: Dict[str, object] = {}
        staged_bc: Dict[Tuple[str, int], tuple] = {}
        for a in scope:
            topo = self.topology(a)
            if (u, v) not in topo.cap and (v, u) not in topo.cap:
                continue        # the fault is not on this axis's fabric
            if a in self._alltoall_scheds:
                from repro.core.repair import RepairError
                raise RepairError(
                    f"axis {a!r} holds a compiled alltoall program and "
                    f"repair does not support alltoall — rebuild the "
                    f"context against the degraded fabric instead (nothing "
                    f"was swapped)")
            axis_reports = []
            degraded: Optional[DiGraph] = None
            if a in self._cache:
                ax = self._cache[a]
                ag2, rep_ag = self.collectives.repair(ax.ag_sched, spec)
                rs2, rep_rs = self.collectives.repair(ax.rs_sched, spec)
                axis_reports += [rep_ag, rep_rs]
                degraded = ag2.topo
                staged_axis[a] = AxisSchedules(
                    axis_name=a, topology=ag2.topo,
                    ag_sched=ag2, rs_sched=rs2,
                    ag_prog=self.collectives.lower(ag2),
                    rs_prog=self.collectives.lower(rs2))
            if a in self._allreduce:
                ar2, rep = self.collectives.repair(self._allreduce[a], spec)
                axis_reports.append(rep)
                degraded = ar2.topo
                staged_ar[a] = ar2
            for (ax_name, root), sched in self._broadcast_scheds.items():
                if ax_name != a:
                    continue
                b2, rep = self.collectives.repair(sched, spec)
                axis_reports.append(rep)
                degraded = b2.topo
                staged_bc[(ax_name, root)] = (b2, self.collectives.lower(b2))
            if degraded is None:        # nothing compiled yet on this axis
                degraded = spec.apply(topo)
            staged_topo[a] = degraded
            reports[a] = axis_reports
        if not reports:
            raise ValueError(f"{spec} applies to no axis of this mesh "
                             f"(axes {scope})")
        # commit — nothing above mutated live state, so a failed repair
        # leaves every program exactly as it was
        self._topologies.update(staged_topo)
        self._cache.update(staged_axis)
        self._allreduce.update(staged_ar)
        for key, (sched, prog) in staged_bc.items():
            self._broadcast_scheds[key] = sched
            self._broadcast[key] = prog
        return reports

    def compile_stats_report(self) -> str:
        """Per-stage schedule-compiler wall times for every artifact this
        context has acquired so far (cache hits report the stage times of
        the original compilation, replayed from the stats sidecar)."""
        lines = ["schedule compile stages (solve|split|pack|rounds|lower):"]

        def add(tag: str, sched) -> None:
            cs = getattr(sched, "compile_stats", None)
            if cs is not None:
                lines.append(f"  {tag}: {cs.describe()}")

        for a, ax in self._cache.items():
            add(f"{a}", ax.ag_sched)
            add(f"{a}", ax.rs_sched)
        for a, ar in self._allreduce.items():
            add(f"{a}.allreduce", ar.rs)
            add(f"{a}.allreduce", ar.ag)
        for (a, root), sched in self._broadcast_scheds.items():
            add(f"{a}.r{root}", sched)
        for a, sched in self._alltoall_scheds.items():
            add(f"{a}.alltoall", sched)
        if len(lines) == 1:
            return "schedule compile stages: (nothing compiled yet)"
        return "\n".join(lines)

    def describe(self) -> str:
        lines = [f"CollectiveContext P={self.num_chunks}"]
        for a, size in self.mesh_axes.items():
            if size == 1:
                lines.append(f"  axis {a}: trivial (size 1)")
                continue
            ax = self.axis(a)
            lines.append(
                f"  axis {a}: {ax.topology.name} "
                f"1/x*={ax.ag_sched.opt.inv_x_star} k={ax.ag_sched.k} "
                f"AG {ax.ag_prog.describe()} RS {ax.rs_prog.describe()}")
        return "\n".join(lines)
