"""Beyond-paper distributed-optimization layers on top of the tree
collectives: bucketed gradient reduction (overlap hooks) and wire
compression.

* `BucketedAllReduce` — partitions the gradient pytree into ~equal-byte
  buckets; each bucket is reduced independently, so on hardware the bucket
  i+1 reduction overlaps the bucket i optimizer math (and, launched from
  the backward, overlaps backprop compute — the classic DDP trick).  The
  bucket schedule also keeps each tree-pipeline transfer long enough to
  amortise the (P+depth)/P pipeline fill of the paper's schedules.
* `compressed_all_reduce` — casts the wire payload (bf16 by default) while
  accumulating in f32 via the tree reduce-scatter's accumulator; the paper
  optimises bytes-on-the-wire, compression multiplies that directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .collectives import tree_all_reduce
from .executor import PermuteProgram


def partition_buckets(tree: Any, bucket_bytes: int = 64 << 20
                      ) -> List[List[int]]:
    """Greedy partition of flattened leaf indices into ~bucket_bytes groups
    (in reverse order — gradients become ready output-to-input)."""
    leaves = jax.tree_util.tree_leaves(tree)
    buckets: List[List[int]] = [[]]
    size = 0
    for idx in reversed(range(len(leaves))):
        nbytes = int(np.prod(leaves[idx].shape)) * leaves[idx].dtype.itemsize
        if size and size + nbytes > bucket_bytes:
            buckets.append([])
            size = 0
        buckets[-1].append(idx)
        size += nbytes
    return buckets


@dataclasses.dataclass
class BucketedAllReduce:
    rs_prog: PermuteProgram
    ag_prog: PermuteProgram
    axis_name: str
    bucket_bytes: int = 64 << 20
    wire_dtype: Optional[Any] = jnp.bfloat16

    @classmethod
    def from_schedule(cls, ar: Any, axis_name: str,
                      bucket_bytes: int = 64 << 20,
                      wire_dtype: Optional[Any] = jnp.bfloat16
                      ) -> "BucketedAllReduce":
        """Build the gradient hook from ONE `AllReduceSchedule` artifact —
        typically `repro.api.Collectives.schedule(..., kind="allreduce")`
        (cache-backed), so the RS and AG halves replay from a single cached
        `repro.allreduce` entry."""
        from .executor import compile_program
        return cls(rs_prog=compile_program(ar.rs),
                   ag_prog=compile_program(ar.ag), axis_name=axis_name,
                   bucket_bytes=bucket_bytes, wire_dtype=wire_dtype)

    def __call__(self, grads: Any) -> Any:
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        buckets = partition_buckets(grads, self.bucket_bytes)
        out = list(leaves)
        for bucket in buckets:
            flat = jnp.concatenate(
                [jnp.ravel(leaves[i]) for i in bucket]) if len(bucket) > 1 \
                else jnp.ravel(leaves[bucket[0]])
            if self.wire_dtype is not None:
                flat = flat.astype(self.wire_dtype)
            red = tree_all_reduce(flat, self.rs_prog, self.ag_prog,
                                  self.axis_name,
                                  accum_dtype=jnp.float32)
            off = 0
            for i in bucket:
                n = int(np.prod(leaves[i].shape))
                out[i] = red[off:off + n].reshape(
                    leaves[i].shape).astype(leaves[i].dtype)
                off += n
        return jax.tree_util.tree_unflatten(treedef, out)


def compressed_all_reduce(x: jax.Array, rs_prog: PermuteProgram,
                          ag_prog: PermuteProgram, axis_name: str,
                          wire_dtype=jnp.bfloat16) -> jax.Array:
    """All-reduce with bf16 (or fp8) wire payload and f32 accumulation."""
    return tree_all_reduce(x.astype(wire_dtype), rs_prog, ag_prog,
                           axis_name,
                           accum_dtype=jnp.float32).astype(x.dtype)
