"""The one front door: `Collectives` over the compiler / cache / comms stack.

Every way of getting a schedule, a lowered `ppermute` program, or an
executable collective out of this repo goes through one facade::

    from repro.api import Collectives

    coll = Collectives(cache="/tmp/schedules")        # or cache=None
    sched = coll.schedule("torus2d:8x8", kind="allgather", num_chunks=16)
    fam   = coll.family("fig1a", kinds=("allgather", "reduce_scatter"))
    prog  = coll.program("dragonfly:g6,p4", kind="broadcast", root=0)
    fn    = coll.executable("bring:8", kind="allreduce", axis_name="x")

Topology arguments accept a `DiGraph`, a `repro.topo.spec.TopologySpec`, a
committed zoo row name (``"torus8x8_failed"``), or a raw spec string
(``"torus2d:8x8@fail(0-1)"``) — see `repro.topo.spec.resolve_topology`.
Compile knobs travel as a `CompileOptions` (or per-call keyword overrides of
the facade's defaults); with a cache attached, every method is replay-first
(`repro.cache.ScheduleCache` hit path) and misses compile through the staged
`repro.core.plan` pipeline, sharing solve/split/pack across a family.

The older module-level acquisition helpers
(`repro.comms.schedules_for_topology` / `programs_for_topology`) are thin
shims over this facade that raise `ReproDeprecationWarning`; tier-1 promotes
that warning to an error, so no in-repo caller can quietly regress onto
them.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

from repro.core import plan as plan_mod
from repro.core import schedule as schedule_mod
from repro.core.graph import DiGraph
from repro.core.schedule import AllReduceSchedule, PipelineSchedule
from repro.topo.spec import SpecLike, TopologySpec, resolve_topology

Artifact = Union[PipelineSchedule, AllReduceSchedule]

#: collective kinds the facade (and the whole stack) understands
KINDS = ("allgather", "reduce_scatter", "broadcast", "reduce", "allreduce",
         "alltoall")
ROOTED_KINDS = ("broadcast", "reduce")
#: the default `family()` pair — what an allreduce consumer needs
PAIR_KINDS = ("allgather", "reduce_scatter")


class ReproDeprecationWarning(DeprecationWarning):
    """Deprecated repro entry point.  Tier-1 runs with this promoted to an
    error (`pyproject.toml` filterwarnings), so in-repo callers must route
    through `repro.api.Collectives` / `repro.topo.spec.TopologySpec`."""


def warn_deprecated(old: str, new: str, *, stacklevel: int = 3) -> None:
    warnings.warn(f"{old} is deprecated; use {new} instead",
                  ReproDeprecationWarning, stacklevel=stacklevel)


@dataclasses.dataclass(frozen=True)
class CompileOptions:
    """Declarative compile request: everything a schedule acquisition needs
    besides the topology itself.

    ``root=None`` on a rooted kind defaults to the smallest compute node at
    resolve time (the sweep's convention), so ``broadcast`` works out of the
    box; ``verify`` replays every chunk at compile time (fresh compiles
    only — a cache constructed by the facade inherits it as
    ``verify_on_compile``)."""
    kind: str = "allgather"
    root: Optional[int] = None
    num_chunks: int = 8
    fixed_k: Optional[int] = None
    verify: bool = False

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown collective kind {self.kind!r} "
                             f"(one of {KINDS})")
        if self.kind in ROOTED_KINDS and self.fixed_k is not None:
            raise ValueError(f"{self.kind} has no fixed-k variant "
                             f"(k = λ(root))")

    def replace(self, **overrides: Any) -> "CompileOptions":
        return dataclasses.replace(self, **overrides)

    def resolved_root(self, g: DiGraph) -> Optional[int]:
        if self.kind not in ROOTED_KINDS:
            return None
        return self.root if self.root is not None else min(g.compute)


class Collectives:
    """Facade owning the schedule cache and the staged compiler pipeline.

    ``cache`` is ``None`` (always compile), a directory path (an on-disk
    `repro.cache.ScheduleCache` is created there, inheriting ``verify`` as
    its compile-time verification flag), or a ready `ScheduleCache`.
    Remaining keywords set the default `CompileOptions` that per-call
    keywords override."""

    def __init__(self, cache: Any = None, *,
                 options: Optional[CompileOptions] = None,
                 **defaults: Any):
        if options is not None and defaults:
            raise TypeError("pass either options= or default keywords, "
                            "not both")
        self.options = options if options is not None \
            else CompileOptions(**defaults)
        self.cache = self._resolve_cache(cache, self.options.verify)

    @staticmethod
    def _resolve_cache(cache: Any, verify: bool):
        if cache is None or cache == "":
            return None
        if isinstance(cache, (str, bytes)) or hasattr(cache, "__fspath__"):
            from repro.cache.store import ScheduleCache
            return ScheduleCache(cache, verify_on_compile=verify)
        return cache        # a ready ScheduleCache (or test double)

    # -------------------------------------------------------------- #
    # request plumbing
    # -------------------------------------------------------------- #

    def topology(self, topo: SpecLike) -> DiGraph:
        """Resolve any accepted topology form to a `DiGraph`."""
        return resolve_topology(topo)

    def opts(self, opts: Optional[CompileOptions] = None,
             **overrides: Any) -> CompileOptions:
        """Merge per-call overrides onto the facade defaults."""
        base = opts if opts is not None else self.options
        return base.replace(**overrides) if overrides else base

    @contextlib.contextmanager
    def _verify_on_compile(self, verify: bool):
        """Honor a per-call ``verify=True`` on the cache's miss path
        (cache hits replay an already-verified artifact and are never
        re-verified).  Raising the flag only — a cache constructed with
        ``verify=True`` keeps verifying even for ``verify=False`` calls."""
        cache = self.cache
        if not verify or getattr(cache, "verify_on_compile", False):
            yield
            return
        cache.verify_on_compile = True
        try:
            yield
        finally:
            cache.verify_on_compile = False

    # -------------------------------------------------------------- #
    # schedules
    # -------------------------------------------------------------- #

    def schedule(self, topo: SpecLike,
                 opts: Optional[CompileOptions] = None,
                 **overrides: Any) -> Artifact:
        """One compiled artifact (`PipelineSchedule`, or
        `AllReduceSchedule` for ``kind="allreduce"``), cache-first."""
        g = self.topology(topo)
        o = self.opts(opts, **overrides)
        root = o.resolved_root(g)
        if self.cache is not None:
            with self._verify_on_compile(o.verify):
                if o.kind in ROOTED_KINDS:
                    return getattr(self.cache, o.kind)(
                        g, root=root, num_chunks=o.num_chunks)
                return getattr(self.cache, o.kind)(
                    g, num_chunks=o.num_chunks, fixed_k=o.fixed_k)
        if o.kind in ROOTED_KINDS:
            return getattr(schedule_mod, f"compile_{o.kind}")(
                g, root=root, num_chunks=o.num_chunks, verify=o.verify)
        return getattr(schedule_mod, f"compile_{o.kind}")(
            g, num_chunks=o.num_chunks, fixed_k=o.fixed_k, verify=o.verify)

    def family(self, topo: SpecLike,
               kinds: Sequence[str] = PAIR_KINDS,
               opts: Optional[CompileOptions] = None,
               timings: Optional[Dict[str, float]] = None,
               packed_out: Optional[Dict[str, Any]] = None,
               jobs: int = 1,
               **overrides: Any) -> Dict[str, Artifact]:
        """One topology's collective family compiled together — the §2.1
        solve and the split/pack products shared across kinds
        (`ScheduleCache.family` on the cache path, `plan.compile_family`
        otherwise; byte-identical to per-kind compiles).  ``timings``
        receives per-kind marginal wall seconds; ``packed_out`` (fresh
        compiles only) the pre-rounds plans for P >= depth re-rounding;
        ``jobs > 1`` packs the independent orientations/kinds in worker
        processes (fresh-compile path only — the cache path compiles at
        most one family and keeps its warm-oracle offers in-process)."""
        g = self.topology(topo)
        o = self.opts(opts, **overrides)
        root = (o.replace(kind="broadcast").resolved_root(g)
                if any(k in ROOTED_KINDS for k in kinds) else None)
        if self.cache is not None:
            with self._verify_on_compile(o.verify):
                return self.cache.family(g, kinds, num_chunks=o.num_chunks,
                                         fixed_k=o.fixed_k, root=root,
                                         timings=timings)
        return plan_mod.compile_family(
            g, kinds=kinds, num_chunks=o.num_chunks, root=root,
            fixed_k=o.fixed_k, verify=o.verify, timings=timings,
            packed_out=packed_out, jobs=jobs)

    def pair(self, topo: SpecLike,
             opts: Optional[CompileOptions] = None,
             **overrides: Any) -> Tuple[PipelineSchedule, PipelineSchedule]:
        """(allgather, reduce_scatter) compiled as one family."""
        fam = self.family(topo, PAIR_KINDS, opts, **overrides)
        return fam["allgather"], fam["reduce_scatter"]

    # -------------------------------------------------------------- #
    # online repair
    # -------------------------------------------------------------- #

    def repair(self, artifact: Union[Artifact, SpecLike], transform,
               opts: Optional[CompileOptions] = None, *,
               use_cache: bool = True, verify: bool = True,
               **overrides: Any) -> Tuple[Artifact, Any]:
        """Delta-recompile a compiled artifact for a degraded topology.

        ``artifact`` is a compiled `PipelineSchedule` / `AllReduceSchedule`
        (the fast path: its warm oracle state may still be resident), or
        any topology form — then the base schedule is acquired first via
        `schedule()` with the usual options.  ``transform`` is a
        `repro.topo.spec.TransformSpec` or its text form (``"@fail(0-1)"``,
        ``"@degrade(2-3,cap=1)"``).

        Returns ``(repaired_artifact, RepairReport)``.  The repaired
        artifact is byte-identical to cold-compiling the transformed
        topology and is re-verified on the degraded graph.  With a cache
        attached, the result is stored under its natural degraded-topology
        key plus a transform-keyed ``.repair`` sidecar (schema v5), so the
        same (base, transform) repair replays without compiling; the
        replayed report carries ``cached=True`` and the *original* repair
        wall time.  ``verify=True`` (the default — repair is an online
        safety path) replays every chunk of the repaired schedule through
        the simulator's correctness checker on the degraded graph."""
        from repro.core.repair import (RepairError, RepairReport,
                                       repair_artifact)
        from repro.topo.spec import TransformSpec
        spec = (transform if isinstance(transform, TransformSpec)
                else TransformSpec.parse_text(transform))
        o = self.opts(opts, **overrides)
        if o.fixed_k is not None:
            raise RepairError(
                "repair requires automatic k: the §2.4 fixed-k floor is "
                "not recorded on artifacts and its floor-scaled capacities "
                "do not delta-compose — recompile the degraded topology "
                "cold instead")
        if (getattr(artifact, "kind", None) == "alltoall"
                or (not isinstance(artifact,
                                   (PipelineSchedule, AllReduceSchedule))
                    and o.kind == "alltoall")):
            raise RepairError(
                "repair does not support alltoall artifacts (the merged "
                "per-source scatter rounds are rebuilt whole-cloth from "
                "the packing) — recompile the degraded topology instead")
        if not isinstance(artifact, (PipelineSchedule, AllReduceSchedule)):
            artifact = self.schedule(artifact, opts, **overrides)
        if self.cache is not None and use_cache:
            hit = self.cache.repaired(artifact, spec)
            if hit is not None:
                art, meta = hit
                report = RepairReport.from_dict(meta["report"])
                report.cached = True
                return art, report
        repaired, report = repair_artifact(artifact, spec, verify=verify)
        if self.cache is not None and use_cache:
            self.cache.put_repaired(artifact, spec, repaired, report)
        return repaired, report

    # -------------------------------------------------------------- #
    # lowered programs / executables
    # -------------------------------------------------------------- #

    def lower(self, artifact: Artifact):
        """Stage-5 lowering of a compiled artifact to static `lax.ppermute`
        program(s); an `AllReduceSchedule` lowers to ``(rs_prog,
        ag_prog)`` — the argument order `tree_all_reduce` expects."""
        from repro.comms.executor import compile_program
        if isinstance(artifact, AllReduceSchedule):
            return compile_program(artifact.rs), compile_program(artifact.ag)
        return compile_program(artifact)

    def program(self, topo: SpecLike,
                opts: Optional[CompileOptions] = None, **overrides: Any):
        """Schedule + lower in one step.  ``kind="allreduce"`` returns
        ``(rs_prog, ag_prog)``; every other kind one `PermuteProgram`."""
        return self.lower(self.schedule(topo, opts, **overrides))

    def executable(self, topo: SpecLike, *, axis_name: str,
                   opts: Optional[CompileOptions] = None,
                   **overrides: Any) -> Callable:
        """A ready-to-call collective for use INSIDE `shard_map` over
        ``axis_name``: the schedule is compiled (or replayed), lowered,
        and bound to the matching `repro.comms.collectives.tree_*`
        executor.  Extra keyword arguments of the underlying ``tree_*``
        function (e.g. ``accum_dtype``) pass through the returned
        callable."""
        o = self.opts(opts, **overrides)
        from repro.comms import collectives as tree_mod
        if o.kind == "allreduce":
            rs_prog, ag_prog = self.program(topo, o)

            def run_allreduce(x, **kw):
                return tree_mod.tree_all_reduce(x, rs_prog, ag_prog,
                                                axis_name, **kw)
            return run_allreduce
        prog = self.program(topo, o)
        fn = {
            "allgather": tree_mod.tree_all_gather,
            "reduce_scatter": tree_mod.tree_reduce_scatter,
            "broadcast": tree_mod.tree_broadcast,
            "reduce": tree_mod.tree_reduce,
            "alltoall": tree_mod.tree_all_to_all,
        }[o.kind]

        def run(x, **kw):
            return fn(x, prog, axis_name, **kw)
        return run

    # -------------------------------------------------------------- #
    # introspection
    # -------------------------------------------------------------- #

    def describe(self) -> str:
        cache = self.cache.describe() if self.cache is not None else "none"
        return f"Collectives[{self.options}] cache={cache}"
