"""§2.3 Spanning-tree packing (Algorithm 2, Bérczi–Frank / Schrijver).

Packs k edge-disjoint spanning out-trees rooted at *every* compute node into
the direct-connect graph D* = (Vc, E*) produced by edge splitting.  Identical
trees are kept aggregated as a `TreeClass` with multiplicity m(R) — the
algorithm's runtime is independent of k (strongly polynomial).

The step size µ for adding edge (x,y) to a class is computed with a single
maxflow in the auxiliary network D̄ of Theorem 12:

    µ = min{ g(x,y), m(R1), F(x,y; D̄) − Σ_{i≠1} m(R_i) }       (eq. 4)

Classes that already span Vc can never violate condition (3) (R_i ⊆ S is
impossible for S ⊊ Vc), so they are dropped from the gadget — this keeps D̄
small and is exactly equivalent (their gadget path contributes F and Σ terms
that cancel).

Candidate edges are scanned in (depth-of-tail, head-id) order, which grows
BFS-like trees: minimum-height packing is NP-complete (paper §2.3), but
shallow trees reduce pipeline fill latency, so the heuristic matters in
practice.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .graph import DiGraph, Edge
from .maxflow import FlowNetwork, warm_restore


class PackingError(RuntimeError):
    pass


@dataclasses.dataclass
class TreeClass:
    """m identical partial out-trees rooted at `root`."""
    root: int
    mult: int
    verts: List[int]               # vertices in addition order (root first)
    edges: List[Edge]              # tree edges in addition order
    vset: set = dataclasses.field(default_factory=set)
    depth: Dict[int, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        self.vset = set(self.verts)
        d = {self.root: 0}
        for (a, b) in self.edges:
            d[b] = d[a] + 1
        self.depth = d

    def add_edge(self, e: Edge) -> None:
        """Grow the tree by edge e = (a, b): b joins the vertex order and
        the depth map incrementally (no O(|E|) recomputation)."""
        a, b = e
        self.edges.append(e)
        self.verts.append(b)
        self.vset.add(b)
        self.depth[b] = self.depth[a] + 1

    def depth_of(self, v: int) -> int:
        """Depth of v in the tree (root = 0) — a dict lookup; the map is
        maintained incrementally by `add_edge`."""
        return self.depth[v]

    def parent_map(self) -> Dict[int, int]:
        return {b: a for (a, b) in self.edges}

    def children_map(self) -> Dict[int, List[int]]:
        ch: Dict[int, List[int]] = {}
        for (a, b) in self.edges:
            ch.setdefault(a, []).append(b)
        return ch


def pack_arborescences(dstar: DiGraph, k: int) -> List[TreeClass]:
    """Algorithm 2.  Returns classes with Σ_{classes of u} mult == k for every
    compute node u, edge-disjoint w.r.t. dstar's capacities."""
    demands = {u: k for u in sorted(dstar.compute)}
    classes = pack_rooted_trees(dstar, demands)
    verify_packing(dstar, k, classes)
    return classes


def pack_rooted_trees(dstar: DiGraph,
                      demands: Dict[int, int]) -> List[TreeClass]:
    """Generalised Algorithm 2: pack `demands[u]` spanning out-trees rooted
    at each u (allgather: k per compute node; broadcast: λ at one root)."""
    for w in dstar.switches:
        # isolated switches (left over from edge splitting) are fine
        if any(w in e for e in dstar.cap):
            raise ValueError(
                f"pack expects a compute-only graph; switch {w} "
                f"still has incident edges")
    nodes = sorted(dstar.compute)
    n = len(nodes)
    if n == 1:
        (u, k), = demands.items()
        return [TreeClass(root=u, mult=k, verts=[u], edges=[])]

    g: Dict[Edge, int] = dict(dstar.cap)          # residual edge capacities
    classes: List[TreeClass] = [
        TreeClass(root=u, mult=m, verts=[u], edges=[])
        for u, m in sorted(demands.items()) if m > 0]
    # grow classes to completion one at a time; splits enqueue copies
    queue: List[int] = list(range(len(classes)))
    all_v = set(nodes)

    sinks = sorted(dstar.compute)
    qi = 0
    while qi < len(queue):
        ci = queue[qi]
        cur = classes[ci]
        # Theorem-12 gadget networks, one per tail x, kept *across* picks
        # for the whole growth of this class: a pick no longer rebuilds
        # them — it applies its residual-capacity delta (and any split-off
        # class) to every cached gadget in place.
        gadgets: Dict[int, _MuGadget] = {}
        while cur.vset != all_v:
            picked = False
            # candidate edges: BFS-like order (oldest tail vertex first)
            for x in cur.verts:
                gadget = gadgets.get(x)
                for y in sinks:
                    e = (x, y)
                    if y in cur.vset or g.get(e, 0) <= 0:
                        continue
                    if gadget is None:
                        gadget = _MuGadget(dstar, g, classes, ci, x)
                        gadgets[x] = gadget
                    mu = gadget.mu(y)
                    if mu <= 0:
                        continue
                    rest = None
                    if mu < cur.mult:
                        # split: a copy keeps the old shape with the rest
                        rest = TreeClass(root=cur.root, mult=cur.mult - mu,
                                         verts=list(cur.verts),
                                         edges=list(cur.edges))
                        classes.append(rest)
                        queue.append(len(classes) - 1)
                        cur.mult = mu
                    cur.add_edge(e)
                    g[e] -= cur.mult
                    for gd in gadgets.values():
                        gd.note_pick(e, g[e], rest)
                    picked = True
                    break
                if picked:
                    break
            if not picked:
                raise PackingError(
                    f"no augmenting edge for root {cur.root} with "
                    f"verts={sorted(cur.vset)} — packing condition violated")
        qi += 1

    return classes


class _MuGadget:
    """Theorem 12's auxiliary network D̄ for one tail vertex x, reused
    across every candidate head y (reset_flow between sinks) *and* across
    picks: µ for adding edge (x,y) to classes[ci] is
    min{g(x,y), m(R1), F(x,y; D̄) − Σ m(R_i)}.

    A pick only (a) lowers one residual capacity g(e) and (b) may split off
    a new incomplete class, so `note_pick` rewrites that one edge and
    grafts the split class's s_i node in place instead of rebuilding the
    network (the scan restart used to rebuild every gadget it revisited).
    Other classes never change while classes[ci] grows, so no other state
    can go stale.

    The ∞ stand-in only needs to exceed the flow limit Σm + m(R1), and
    Σm + m(R1) is conserved by splits while g only shrinks, so the value
    sized at build time stays sufficient — the computed µ is identical for
    any sufficiently large value.

    Warm probes: the gadget tracks a target capacity per edge and keeps a
    per-head flow snapshot, so re-probing a head y after picks restores y's
    last x->y flow and applies only the pick deltas (one residual-capacity
    decrease and a grafted class per pick) instead of recomputing the
    Σm-unit base flow from zero.  µ is unchanged: a restored flow at or
    above the limit clamps to `want` exactly as a limit-hit cold maxflow
    does, and below the limit the re-augmented value is the exact F."""

    __slots__ = ("net", "g", "cur", "x", "sum_m", "inf", "eid", "_tgt",
                 "_warm")

    def __init__(self, dstar: DiGraph, g: Dict[Edge, int],
                 classes: Sequence[TreeClass], ci: int, x: int):
        cur = classes[ci]
        # gadget: one node s_i per other *incomplete* class
        others = [c for j, c in enumerate(classes)
                  if j != ci and c.mult > 0
                  and len(c.vset) < dstar.num_compute]
        sum_m = sum(c.mult for c in others)
        inf = sum_m + sum(g.values()) + cur.mult + 1
        edges = [(a, b, c) for (a, b), c in g.items() if c > 0]
        self.eid: Dict[Edge, int] = {
            (a, b): 2 * j for j, (a, b, _) in enumerate(edges)}
        for j, c in enumerate(others):
            sid = dstar.num_nodes + j
            edges.append((x, sid, c.mult))
            edges.extend((sid, v, inf) for v in c.verts)
        self.net = FlowNetwork(dstar.num_nodes + len(others))
        self.net.add_edges(edges)
        self.g, self.cur, self.x = g, cur, x
        self.sum_m, self.inf = sum_m, inf
        self._tgt: List[int] = [c for (_, _, c) in edges]
        # head y -> (cap snapshot, flow value, target snapshot)
        self._warm: Dict[int, Tuple[List[int], int, List[int]]] = {}

    def note_pick(self, e: Edge, new_cap: int,
                  rest: Optional[TreeClass]) -> None:
        """Apply a pick's delta: edge e's residual capacity dropped to
        `new_cap`, and `rest` (if the pick split the class) joins the
        gadget as a fresh incomplete class."""
        eid = self.eid.get(e)
        if eid is None:      # e had capacity 0 at build time (cannot
            eid = self.net.add_edge(*e, 0)    # happen: g never grows), but
            self.eid[e] = eid                 # stay safe
            self._tgt.append(0)
        self.net.set_edge_cap(eid, new_cap)
        self._tgt[eid >> 1] = new_cap
        if rest is not None:
            sid = self.net.add_node()
            self.net.add_edge(self.x, sid, rest.mult)
            self._tgt.append(rest.mult)
            self.net.add_edges((sid, v, self.inf) for v in rest.verts)
            self._tgt.extend(self.inf for _ in rest.verts)
            self.sum_m += rest.mult

    def mu(self, y: int) -> int:
        want = min(self.g[(self.x, y)], self.cur.mult)
        limit = self.sum_m + want
        state = self._warm.get(y)
        if state is None:
            self.net.reset_flow()
            f = self.net.maxflow(self.x, y, limit=limit)
        else:
            f = warm_restore(self.net, self._tgt, state, self.x, y, limit)
        self._warm[y] = (list(self.net.cap), f, list(self._tgt))
        return min(want, f - self.sum_m)


# ---------------------------------------------------------------------- #
# Verification (used by tests and by the schedule builder in verify mode)
# ---------------------------------------------------------------------- #

def verify_packing(dstar: DiGraph, k: int,
                   classes: Sequence[TreeClass]) -> None:
    """Assert the Algorithm-2 output contract:
    * every class is a spanning out-tree rooted at its root;
    * per root, multiplicities sum to k;
    * edge-disjoint: per edge, Σ mult of classes using it <= capacity."""
    verify_rooted_packing(dstar, {u: k for u in sorted(dstar.compute)},
                          classes)


def verify_rooted_packing(dstar: DiGraph, demands: Dict[int, int],
                          classes: Sequence[TreeClass]) -> None:
    """Demand-weighted contract of `pack_rooted_trees`: spanning out-trees,
    per-root multiplicities summing to demands[root], edge-disjointness
    (used both by allgather, demands ≡ k, and broadcast, {root: λ})."""
    nodes = sorted(dstar.compute)
    per_root: Dict[int, int] = {u: 0 for u in demands}
    load: Dict[Edge, int] = {}
    for c in classes:
        if c.mult <= 0:
            raise PackingError(f"class with non-positive multiplicity {c.mult}")
        per_root[c.root] += c.mult
        if set(c.verts) != set(nodes):
            raise PackingError(f"root {c.root}: tree does not span Vc")
        if len(c.edges) != len(nodes) - 1:
            raise PackingError(f"root {c.root}: {len(c.edges)} edges != N-1")
        indeg: Dict[int, int] = {}
        reach = {c.root}
        for (a, b) in c.edges:          # edges are in addition order
            indeg[b] = indeg.get(b, 0) + 1
            if a not in reach:
                raise PackingError(f"root {c.root}: edge {(a,b)} detached")
            reach.add(b)
        if any(d != 1 for d in indeg.values()) or c.root in indeg:
            raise PackingError(f"root {c.root}: not an out-tree")
        for e in c.edges:
            load[e] = load.get(e, 0) + c.mult
    for u, total in per_root.items():
        if total != demands[u]:
            raise PackingError(
                f"root {u}: multiplicities sum to {total} != {demands[u]}")
    for e, used in load.items():
        if used > dstar.cap.get(e, 0):
            raise PackingError(
                f"edge {e}: load {used} exceeds capacity {dstar.cap.get(e, 0)}")


def max_tree_depth(classes: Sequence[TreeClass]) -> int:
    return max((max(c.depth.values(), default=0) for c in classes),
               default=0)
