"""§2.3 Spanning-tree packing (Algorithm 2, Bérczi–Frank / Schrijver).

Packs k edge-disjoint spanning out-trees rooted at *every* compute node into
the direct-connect graph D* = (Vc, E*) produced by edge splitting.  Identical
trees are kept aggregated as a `TreeClass` with multiplicity m(R) — the
algorithm's runtime is independent of k (strongly polynomial).

The step size µ for adding edge (x,y) to a class is computed with a single
maxflow in the auxiliary network D̄ of Theorem 12:

    µ = min{ g(x,y), m(R1), F(x,y; D̄) − Σ_{i≠1} m(R_i) }       (eq. 4)

Classes that already span Vc can never violate condition (3) (R_i ⊆ S is
impossible for S ⊊ Vc), so they are dropped from the gadget — this keeps D̄
small and is exactly equivalent (their gadget path contributes F and Σ terms
that cancel).

Candidate edges are scanned in (depth-of-tail, head-id) order, which grows
BFS-like trees: minimum-height packing is NP-complete (paper §2.3), but
shallow trees reduce pipeline fill latency, so the heuristic matters in
practice.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set

from .graph import DiGraph, Edge
from .maxflow import FlowNetwork


class PackingError(RuntimeError):
    pass


@dataclasses.dataclass
class TreeClass:
    """m identical partial out-trees rooted at `root`."""
    root: int
    mult: int
    verts: List[int]               # vertices in addition order (root first)
    edges: List[Edge]              # tree edges in addition order
    vset: set = dataclasses.field(default_factory=set)
    depth: Dict[int, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        self.vset = set(self.verts)
        d = {self.root: 0}
        for (a, b) in self.edges:
            d[b] = d[a] + 1
        self.depth = d

    def add_edge(self, e: Edge) -> None:
        """Grow the tree by edge e = (a, b): b joins the vertex order and
        the depth map incrementally (no O(|E|) recomputation)."""
        a, b = e
        self.edges.append(e)
        self.verts.append(b)
        self.vset.add(b)
        self.depth[b] = self.depth[a] + 1

    def depth_of(self, v: int) -> int:
        """Depth of v in the tree (root = 0) — a dict lookup; the map is
        maintained incrementally by `add_edge`."""
        return self.depth[v]

    def parent_map(self) -> Dict[int, int]:
        return {b: a for (a, b) in self.edges}

    def children_map(self) -> Dict[int, List[int]]:
        ch: Dict[int, List[int]] = {}
        for (a, b) in self.edges:
            ch.setdefault(a, []).append(b)
        return ch


def pack_arborescences(dstar: DiGraph, k: int) -> List[TreeClass]:
    """Algorithm 2.  Returns classes with Σ_{classes of u} mult == k for every
    compute node u, edge-disjoint w.r.t. dstar's capacities."""
    demands = {u: k for u in sorted(dstar.compute)}
    classes = pack_rooted_trees(dstar, demands)
    verify_packing(dstar, k, classes)
    return classes


def pack_rooted_trees(dstar: DiGraph,
                      demands: Dict[int, int]) -> List[TreeClass]:
    """Generalised Algorithm 2: pack `demands[u]` spanning out-trees rooted
    at each u (allgather: k per compute node; broadcast: λ at one root)."""
    for w in dstar.switches:
        # isolated switches (left over from edge splitting) are fine
        if any(w in e for e in dstar.cap):
            raise ValueError(
                f"pack expects a compute-only graph; switch {w} "
                f"still has incident edges")
    nodes = sorted(dstar.compute)
    n = len(nodes)
    if n == 1:
        (u, k), = demands.items()
        return [TreeClass(root=u, mult=k, verts=[u], edges=[])]

    g: Dict[Edge, int] = dict(dstar.cap)          # residual edge capacities
    classes: List[TreeClass] = [
        TreeClass(root=u, mult=m, verts=[u], edges=[])
        for u, m in sorted(demands.items()) if m > 0]
    # grow classes to completion one at a time; splits enqueue copies
    queue: List[int] = list(range(len(classes)))
    all_v = set(nodes)

    sinks = sorted(dstar.compute)
    qi = 0
    while qi < len(queue):
        ci = queue[qi]
        cur = classes[ci]
        # ONE Theorem-12 gadget network for the whole growth of this class,
        # shared across every candidate tail x (toggleable tail edges — see
        # `_MuGadget`) and kept *across* picks: a pick applies its residual-
        # capacity delta (and any split-off class) to the gadget in place.
        gadget: Optional[_MuGadget] = None
        # (x, y) candidates whose µ came back <= 0 for this class growth.
        # µ is monotonically non-increasing while the class grows (picks
        # only shrink g and want, and a split raises Σm by exactly the
        # amount F can gain through the grafted s_i), so a rejected
        # candidate stays rejected — and by the same argument the scan is
        # *resumable*: after a pick at position (xi, yi) every candidate
        # before it is still rejected for its original reason (vset only
        # grows, g never rises, µ never rises), so instead of restarting
        # the (tail, head) sweep from scratch each pick continues it in
        # place.  A re-validation pass below guards the invariant: on a
        # stall the cache is dropped and the sweep restarts from zero once
        # before the packing condition is declared violated.
        negative: Set[Edge] = set()
        revalidated = False
        xi = yi = 0
        while cur.vset != all_v:
            picked = False
            # candidate edges: BFS-like order (oldest tail vertex first)
            while xi < len(cur.verts):
                x = cur.verts[xi]
                while yi < len(sinks):
                    y = sinks[yi]
                    yi += 1
                    e = (x, y)
                    if y in cur.vset or g.get(e, 0) <= 0 or e in negative:
                        continue
                    if gadget is None:
                        gadget = _MuGadget(dstar, g, classes, ci)
                    mu = gadget.mu(x, y)
                    if mu <= 0:
                        negative.add(e)
                        continue
                    rest = None
                    if mu < cur.mult:
                        # split: a copy keeps the old shape with the rest
                        rest = TreeClass(root=cur.root, mult=cur.mult - mu,
                                         verts=list(cur.verts),
                                         edges=list(cur.edges))
                        classes.append(rest)
                        queue.append(len(classes) - 1)
                        cur.mult = mu
                    cur.add_edge(e)
                    g[e] -= cur.mult
                    gadget.note_pick(e, g[e], rest)
                    picked = True
                    revalidated = False
                    break
                if picked:
                    break
                xi += 1
                yi = 0
            if not picked:
                if negative and not revalidated:
                    # re-validation pass: the cache rests on µ monotonicity;
                    # before declaring the packing condition violated, drop
                    # every cached rejection (and the gadget whose residual
                    # state produced them) and rescan from scratch once.
                    negative.clear()
                    gadget = None
                    revalidated = True
                    xi = yi = 0
                    continue
                raise PackingError(
                    f"no augmenting edge for root {cur.root} with "
                    f"verts={sorted(cur.vset)} — packing condition violated")
        qi += 1

    return classes


class _MuGadget:
    """Theorem 12's auxiliary network D̄ for the growth of one class,
    shared across every candidate tail x and head y: µ for adding edge
    (x,y) to classes[ci] is  min{g(x,y), m(R1), F(x,y; D̄) − Σ m(R_i)}.

    The network D̄ of the paper attaches one node s_i per other
    *incomplete* class, with an edge x -> s_i of capacity m(R_i) from the
    candidate tail.  Those tail edges are the only x-dependent part, so
    instead of one network per tail the gadget routes them through a hub:
    a single hub node h with h -> s_i of capacity m(R_i), plus a
    toggleable u -> h edge per compute vertex — exactly one of them (the
    probed tail's, at the ∞ stand-in) is active per probe.  Every unit of
    s_i inflow still originates at x and is still capped at m(R_i), so
    F(x, y) is exactly the paper's value, and switching tails is two
    capacity writes instead of a network build.

    A pick only (a) lowers one residual capacity g(e) and (b) may split
    off a new incomplete class, so `note_pick` rewrites that one edge and
    grafts the split class's s_i node in place (hub edge + ∞ fan-out)
    instead of rebuilding.  Other classes never change while classes[ci]
    grows, so no other state can go stale.

    The ∞ stand-in only needs to exceed the flow limit Σm + m(R1), and
    Σm + m(R1) is conserved by splits while g only shrinks, so the value
    sized at build time stays sufficient — the computed µ is identical
    for any sufficiently large value.

    Fast accept: edge (x,y) itself and the Σm − miss(y) units routable
    x -> h -> s_i -> y through classes that already contain y are
    edge-disjoint flows, so F ≥ g(x,y) + Σm − miss(y) (miss(y) = Σ m(R_i)
    over incomplete classes *not* containing y).  When g(x,y) − miss(y)
    ≥ min{g(x,y), m(R1)} this lower bound already pins µ = want, and the
    probe returns without running a maxflow at all."""

    __slots__ = ("net", "g", "cur", "sum_m", "inf", "eid", "tail_eid",
                 "hub", "miss", "cur_tail")

    def __init__(self, dstar: DiGraph, g: Dict[Edge, int],
                 classes: Sequence[TreeClass], ci: int):
        cur = classes[ci]
        # gadget: one node s_i per other *incomplete* class
        others = [c for j, c in enumerate(classes)
                  if j != ci and c.mult > 0
                  and len(c.vset) < dstar.num_compute]
        sum_m = sum(c.mult for c in others)
        inf = sum_m + sum(g.values()) + cur.mult + 1
        edges = [(a, b, c) for (a, b), c in g.items() if c > 0]
        self.eid: Dict[Edge, int] = {
            (a, b): 2 * j for j, (a, b, _) in enumerate(edges)}
        hub = dstar.num_nodes
        tails = sorted(dstar.compute)
        self.tail_eid: Dict[int, int] = {
            u: 2 * (len(edges) + j) for j, u in enumerate(tails)}
        edges.extend((u, hub, 0) for u in tails)
        for j, c in enumerate(others):
            sid = hub + 1 + j
            edges.append((hub, sid, c.mult))
            edges.extend((sid, v, inf) for v in c.verts)
        self.net = FlowNetwork(hub + 1 + len(others))
        self.net.add_edges(edges)
        self.g, self.cur = g, cur
        self.sum_m, self.inf = sum_m, inf
        self.hub = hub
        self.miss: Dict[int, int] = {
            y: sum(c.mult for c in others if y not in c.vset)
            for y in tails}
        self.cur_tail: Optional[int] = None

    def note_pick(self, e: Edge, new_cap: int,
                  rest: Optional[TreeClass]) -> None:
        """Apply a pick's delta: edge e's residual capacity dropped to
        `new_cap`, and `rest` (if the pick split the class) joins the
        gadget as a fresh incomplete class."""
        eid = self.eid.get(e)
        if eid is None:      # e had capacity 0 at build time (cannot
            eid = self.net.add_edge(*e, 0)    # happen: g never grows), but
            self.eid[e] = eid                 # stay safe
        self.net.set_edge_cap(eid, new_cap)
        if rest is not None:
            sid = self.net.add_node()
            self.net.add_edge(self.hub, sid, rest.mult)
            self.net.add_edges((sid, v, self.inf) for v in rest.verts)
            self.sum_m += rest.mult
            for y in self.miss:
                if y not in rest.vset:
                    self.miss[y] += rest.mult

    def mu(self, x: int, y: int) -> int:
        want = min(self.g[(x, y)], self.cur.mult)
        if self.g[(x, y)] - self.miss[y] >= want:
            return want          # lower bound pins µ (see class docstring)
        if x != self.cur_tail:
            if self.cur_tail is not None:
                self.net.set_edge_cap(self.tail_eid[self.cur_tail], 0)
            self.net.set_edge_cap(self.tail_eid[x], self.inf)
            self.cur_tail = x
        limit = self.sum_m + want
        self.net.reset_flow()
        f = self.net.maxflow(x, y, limit=limit)
        return min(want, f - self.sum_m)


# ---------------------------------------------------------------------- #
# Verification (used by tests and by the schedule builder in verify mode)
# ---------------------------------------------------------------------- #

def verify_packing(dstar: DiGraph, k: int,
                   classes: Sequence[TreeClass]) -> None:
    """Assert the Algorithm-2 output contract:
    * every class is a spanning out-tree rooted at its root;
    * per root, multiplicities sum to k;
    * edge-disjoint: per edge, Σ mult of classes using it <= capacity."""
    verify_rooted_packing(dstar, {u: k for u in sorted(dstar.compute)},
                          classes)


def verify_rooted_packing(dstar: DiGraph, demands: Dict[int, int],
                          classes: Sequence[TreeClass]) -> None:
    """Demand-weighted contract of `pack_rooted_trees`: spanning out-trees,
    per-root multiplicities summing to demands[root], edge-disjointness
    (used both by allgather, demands ≡ k, and broadcast, {root: λ})."""
    nodes = sorted(dstar.compute)
    per_root: Dict[int, int] = {u: 0 for u in demands}
    load: Dict[Edge, int] = {}
    for c in classes:
        if c.mult <= 0:
            raise PackingError(f"class with non-positive multiplicity {c.mult}")
        per_root[c.root] += c.mult
        if set(c.verts) != set(nodes):
            raise PackingError(f"root {c.root}: tree does not span Vc")
        if len(c.edges) != len(nodes) - 1:
            raise PackingError(f"root {c.root}: {len(c.edges)} edges != N-1")
        indeg: Dict[int, int] = {}
        reach = {c.root}
        for (a, b) in c.edges:          # edges are in addition order
            indeg[b] = indeg.get(b, 0) + 1
            if a not in reach:
                raise PackingError(f"root {c.root}: edge {(a,b)} detached")
            reach.add(b)
        if any(d != 1 for d in indeg.values()) or c.root in indeg:
            raise PackingError(f"root {c.root}: not an out-tree")
        for e in c.edges:
            load[e] = load.get(e, 0) + c.mult
    for u, total in per_root.items():
        if total != demands[u]:
            raise PackingError(
                f"root {u}: multiplicities sum to {total} != {demands[u]}")
    for e, used in load.items():
        if used > dstar.cap.get(e, 0):
            raise PackingError(
                f"edge {e}: load {used} exceeds capacity {dstar.cap.get(e, 0)}")


def max_tree_depth(classes: Sequence[TreeClass]) -> int:
    return max((max(c.depth.values(), default=0) for c in classes),
               default=0)
