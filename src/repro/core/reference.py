"""Reference oracles: slow, obviously-correct re-implementations straight
from the paper's pseudocode.

Every function here trades all of the production engine's machinery —
numpy/scipy substrates, warm-started flows, shared gadget networks,
candidate caching — for the most literal possible transcription of the
paper: plain-dict Edmonds–Karp maxflow, a fresh network per probe, a full
candidate rescan per pick.  `tests/test_reference_differential.py` pins the
fast path to these functions over seeded random and zoo topologies, so any
optimization that changes a verdict (not just its cost) fails loudly.

Paper mapping (see docs/ALGORITHM.md for the line-by-line version):

* `reference_maxflow`            — the F(·,·) primitive every theorem uses
* `reference_min_flow_from_source` — Theorem 5/7 quantity
                                     min_v F(s, v; D_k)
* `reference_feasible`           — Theorem 7 condition
                                     min_v F(s, v; D_k) >= |Vc| k
* `reference_split_cap`          — Theorem 8 / eq. (2) maximum splittable M
* `reference_mu`                 — Theorem 12 / eq. (4) step size µ
* `reference_pack_rooted_trees`  — Algorithm 2 (generalised, per-root
                                    demands), fresh µ oracle per candidate
* `reference_pack_arborescences` — Algorithm 2 with demands ≡ k

The production counterparts are `FlowNetwork.maxflow` /
`min_flow_from_source` (core.maxflow), `_TheoremEightProber.split_cap`
(core.edge_split), and `_MuGadget.mu` / `pack_rooted_trees` /
`pack_arborescences` (core.arborescence).
"""
from __future__ import annotations

from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .arborescence import PackingError, TreeClass
from .graph import DiGraph, Edge


def reference_maxflow(edges: Iterable[Tuple[int, int, int]], s: int, t: int,
                      limit: Optional[int] = None) -> int:
    """Edmonds–Karp on a plain dict residual graph: repeatedly push along a
    BFS-shortest augmenting path.  Parallel edges merge (flow values are
    distribution-independent).  Returns exactly ``min(F(s, t), limit)`` —
    the same contract as `FlowNetwork.maxflow`."""
    if s == t:
        raise ValueError("source == sink")
    cap: Dict[Edge, int] = {}
    adj: Dict[int, set] = {}
    for u, v, c in edges:
        if c < 0:
            raise ValueError(f"negative capacity on ({u}, {v})")
        cap[(u, v)] = cap.get((u, v), 0) + c
        cap.setdefault((v, u), 0)
        adj.setdefault(u, set()).add(v)
        adj.setdefault(v, set()).add(u)
    flow = 0
    while limit is None or flow < limit:
        parent: Dict[int, int] = {s: s}
        queue = deque([s])
        while queue and t not in parent:
            u = queue.popleft()
            for v in sorted(adj.get(u, ())):
                if v not in parent and cap[(u, v)] > 0:
                    parent[v] = u
                    queue.append(v)
        if t not in parent:
            break
        path = []
        v = t
        while v != s:
            path.append((parent[v], v))
            v = parent[v]
        aug = min(cap[e] for e in path)
        if limit is not None:
            aug = min(aug, limit - flow)
        for (a, b) in path:
            cap[(a, b)] -= aug
            cap[(b, a)] += aug
        flow += aug
    return flow


# ---------------------------------------------------------------------- #
# Theorems 5/7/8 — the edge-splitting oracles
# ---------------------------------------------------------------------- #

def _dk_edges(d: DiGraph, k: int) -> Tuple[int, List[Tuple[int, int, int]]]:
    """(super_source, edges) of D_k: the graph plus a super source tied to
    every compute node with capacity k."""
    s = d.num_nodes
    edges = [(a, b, c) for (a, b), c in sorted(d.cap.items())]
    edges.extend((s, u, k) for u in sorted(d.compute))
    return s, edges


def reference_min_flow_from_source(d: DiGraph, k: int) -> int:
    """Theorem 5/7 quantity: min_v F(s, v; D_k) over compute sinks v."""
    s, edges = _dk_edges(d, k)
    return min(reference_maxflow(edges, s, v) for v in sorted(d.compute))


def reference_feasible(d: DiGraph, k: int) -> bool:
    """Theorem 7: D_k admits the packing iff min_v F(s, v) >= |Vc| k."""
    return reference_min_flow_from_source(d, k) >= d.num_compute * k


def reference_split_cap(d: DiGraph, k: int, u: int, w: int, t: int) -> int:
    """Theorem 8 / eq. (2): the maximum M such that splitting the pair
    (u, w), (w, t) by M preserves the Theorem-7 condition.  Every term of
    the minimum is evaluated with a fresh D̂ network and a cold maxflow:

        M = min{ c(u,w), c(w,t),
                 min_{v != u}  F(u, w; D̂_(u,w),v) − |Vc| k,
                 min_v         F(w, t; D̂_(w,t),v) − |Vc| k }

    where D̂_(a,b),v is D_k plus ∞ edges making the term finite exactly on
    the paper's witness cuts: (u, s) and (u, t) in both, plus the per-sink
    probe edge (v, w) resp. (v, t) (v = t probes plain F(w, t))."""
    if u == t:
        raise ValueError("degenerate pair (u == t) is not covered by "
                         "Theorem 8 (use the Theorem-5 discard search)")
    bound = min(d.cap.get((u, w), 0), d.cap.get((w, t), 0))
    if bound <= 0:
        return 0
    nk = d.num_compute * k
    s, base_edges = _dk_edges(d, k)
    inf = 2 * sum(d.cap.values()) + nk + 1
    best = bound
    for v in sorted(d.compute):          # term 3: F(u, w; D̂_(u,w),v)
        if v == u:
            continue                     # ∞ probe (v,w)=(u,w) → F infinite
        edges = base_edges + [(u, s, inf), (u, t, inf), (v, w, inf)]
        best = min(best, reference_maxflow(edges, u, w) - nk)
        if best <= 0:
            return 0
    for v in sorted(d.compute):          # term 4: F(w, t; D̂_(w,t),v)
        edges = base_edges + [(w, s, inf), (u, t, inf)]
        if v != t:
            edges.append((v, t, inf))
        best = min(best, reference_maxflow(edges, w, t) - nk)
        if best <= 0:
            return 0
    return best


# ---------------------------------------------------------------------- #
# Theorem 12 / Algorithm 2 — tree packing
# ---------------------------------------------------------------------- #

def reference_mu(dstar: DiGraph, g: Dict[Edge, int],
                 classes: Sequence[TreeClass], ci: int,
                 x: int, y: int) -> int:
    """Theorem 12 / eq. (4): the step size for growing classes[ci] by edge
    (x, y), from a D̄ network built fresh for this single probe:

        µ = min{ g(x,y), m(R1), F(x,y; D̄) − Σ_{i≠1} m(R_i) }

    D̄ carries the residual capacities g plus, per other *incomplete* class
    R_i, a node s_i with x → s_i of capacity m(R_i) and ∞ edges s_i → v
    for every v already in R_i.  (Complete classes can never violate the
    packing condition, so they are omitted — exactly as in the production
    gadget.)"""
    cur = classes[ci]
    others = [c for j, c in enumerate(classes)
              if j != ci and c.mult > 0
              and len(c.vset) < dstar.num_compute]
    sum_m = sum(c.mult for c in others)
    inf = sum_m + sum(g.values()) + cur.mult + 1
    edges = [(a, b, c) for (a, b), c in sorted(g.items()) if c > 0]
    for j, c in enumerate(others):
        sid = dstar.num_nodes + j
        edges.append((x, sid, c.mult))
        edges.extend((sid, v, inf) for v in sorted(c.vset))
    f = reference_maxflow(edges, x, y)
    return min(g[(x, y)], cur.mult, f - sum_m)


def reference_pack_rooted_trees(dstar: DiGraph,
                                demands: Dict[int, int]) -> List[TreeClass]:
    """Algorithm 2, literally: grow each class to spanning, re-scanning
    every candidate edge in (depth-of-tail, head-id) order after every pick
    and computing µ with a fresh `reference_mu` network per candidate.  The
    candidate order matches the production packer exactly, and µ is exact
    on both sides, so the class list (roots, multiplicities, vertex and
    edge orders) is identical to `pack_rooted_trees`."""
    for w in dstar.switches:
        if any(w in e for e in dstar.cap):
            raise ValueError(
                f"pack expects a compute-only graph; switch {w} "
                f"still has incident edges")
    nodes = sorted(dstar.compute)
    if len(nodes) == 1:
        (u, k), = demands.items()
        return [TreeClass(root=u, mult=k, verts=[u], edges=[])]

    g: Dict[Edge, int] = dict(dstar.cap)
    classes: List[TreeClass] = [
        TreeClass(root=u, mult=m, verts=[u], edges=[])
        for u, m in sorted(demands.items()) if m > 0]
    queue: List[int] = list(range(len(classes)))
    all_v = set(nodes)
    qi = 0
    while qi < len(queue):
        ci = queue[qi]
        cur = classes[ci]
        while cur.vset != all_v:
            picked = False
            for x in cur.verts:
                for y in nodes:
                    e = (x, y)
                    if y in cur.vset or g.get(e, 0) <= 0:
                        continue
                    mu = reference_mu(dstar, g, classes, ci, x, y)
                    if mu <= 0:
                        continue
                    if mu < cur.mult:
                        rest = TreeClass(root=cur.root, mult=cur.mult - mu,
                                         verts=list(cur.verts),
                                         edges=list(cur.edges))
                        classes.append(rest)
                        queue.append(len(classes) - 1)
                        cur.mult = mu
                    cur.add_edge(e)
                    g[e] -= cur.mult
                    picked = True
                    break
                if picked:
                    break
            if not picked:
                raise PackingError(
                    f"no augmenting edge for root {cur.root} with "
                    f"verts={sorted(cur.vset)} — packing condition violated")
        qi += 1
    return classes


def reference_pack_arborescences(dstar: DiGraph, k: int) -> List[TreeClass]:
    """Algorithm 2 with demands ≡ k (allgather: k spanning out-trees per
    compute root)."""
    return reference_pack_rooted_trees(
        dstar, {u: k for u in sorted(dstar.compute)})
