"""§2.4 Fixed-k optimality.

The minimal k from Proposition 3 can be as large as min_v B-(v)/gcd(b_e);
practical pipelines want few trees per root.  Theorems 13-15 let us binary
search the best achievable runtime (M/Nk)·U* for a *given* k, within
(M/Nk)/min_e b_e of the true optimum (Theorem 15).
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction

from .graph import DiGraph
from .maxflow import SourcedNetwork
from .optimality import simplest_between


def _fixed_k_net(g: DiGraph, k: int) -> SourcedNetwork:
    """One Theorem-14 oracle network per search; probes refloor every
    capacity (no warm-startable delta — see `optimality._oracle_net`), but
    the sink sweep adapts so infeasible probes fail on the first maxflow."""
    return SourcedNetwork(g, {u: k for u in sorted(g.compute)})


def _feasible_on(net: SourcedNetwork, k: int, U: Fraction) -> bool:
    net.floor_graph_caps(U)
    return net.min_source_flow_at_least(sorted(net.g.compute),
                                        net.g.num_compute * k)


def fixed_k_feasible(g: DiGraph, k: int, U: Fraction) -> bool:
    """Theorem 14 oracle: does G({⌊U b_e⌋}) pack k trees per root?
    (Theorem 5: min_v F(s, v; G_k(⌊U b_e⌋)) >= |Vc| k.)"""
    return _feasible_on(_fixed_k_net(g, k), k, U)


@dataclasses.dataclass(frozen=True)
class FixedKResult:
    k: int
    U_star: Fraction           # best (M/Nk)·U* runtime for this k
    runtime_factor: Fraction   # U*/k, in (M/N)/bandwidth units — compare 1/x*


def solve_fixed_k(g: DiGraph, k: int) -> FixedKResult:
    """Binary search of §2.4 for the exact rational U*."""
    n = g.num_compute
    if n == 1:
        return FixedKResult(k, Fraction(0), Fraction(0))
    dmin = g.min_compute_ingress()
    max_b = max(g.cap.values())
    lo = Fraction((n - 1) * k, dmin)
    hi = Fraction((n - 1) * k)
    net = _fixed_k_net(g, k)      # one network serves every probe below
    if _feasible_on(net, k, lo):
        return FixedKResult(k, lo, lo / k)
    gap = Fraction(1, max_b * max_b)
    while hi - lo > gap:
        mid = (lo + hi) / 2
        if _feasible_on(net, k, mid):
            hi = mid
        else:
            lo = mid
    cand = simplest_between(lo, hi)
    assert cand.denominator <= max_b, (cand, max_b)
    assert _feasible_on(net, k, cand), f"recovered U*={cand} infeasible"
    return FixedKResult(k, cand, cand / k)
