"""Lower bounds: allgather (1), broadcast (5), allreduce (6)+(7), Theorem 19.

All bounds are returned as *runtime factors* in units of (data bytes) /
(bandwidth unit): multiply by M/bandwidth-unit to get seconds.

  allgather/reduce-scatter:  T >= (M/N) * inv_x_star              (1)
  broadcast:                 T >= M / min-compute-cut             (5)
  reduce:                    T >= M / min-compute-cut of G^T      (5 dual)
  allreduce:                 T >= M / min-compute-cut             (6)
  allreduce (Patarasuk-Yuan):T >= 2M(N-1)/N / max_v single-node-cut (7)
  alltoall:                  T >= (M/N) max_S |S∩Vc|(N-|S∩Vc|)/B+(S)

Per-root variants (`broadcast_root_lb`, `reduce_root_lb`) give the exact
bound a single-root schedule converges to: M / λ(root).
"""
from __future__ import annotations

import itertools
from fractions import Fraction
from typing import Dict, List, Optional, Set, Tuple

from .graph import DiGraph
from .maxflow import FlowNetwork, build_network
from .optimality import allgather_inv_xstar


def min_compute_separating_cut(g: DiGraph) -> int:
    """min_{S: S∩Vc ∉ {∅,Vc}} B+_G(S).

    For Eulerian G this equals min over v of F(v0, v; G) for any fixed
    compute node v0 (cuts not containing v0 have Eulerian-equal complements
    that do)."""
    vc = sorted(g.compute)
    if len(vc) < 2:
        raise ValueError("need >= 2 compute nodes")
    v0 = vc[0]
    best = None
    for v in vc[1:]:
        net = build_network(g)
        f = net.maxflow(v0, v)
        best = f if best is None else min(best, f)
        # Eulerian symmetry: also the reverse direction
        net = build_network(g)
        f = net.maxflow(v, v0)
        best = min(best, f)
    return best


def single_node_cut(g: DiGraph, v: int) -> int:
    """min_{S: S∩Vc = {v}} B+_G(S): maxflow from v to a super-sink tied to
    every other compute node with ∞ capacity."""
    inf = sum(g.cap.values()) + 1
    net = FlowNetwork(g.num_nodes + 1)
    t = g.num_nodes
    for (a, b), c in g.cap.items():
        net.add_edge(a, b, c)
    for u in sorted(g.compute):
        if u != v:
            net.add_edge(u, t, inf)
    return net.maxflow(v, t)


def broadcast_lb(g: DiGraph) -> Fraction:
    """Eq (5): runtime factor M * [min cut]^-1 — per unit M."""
    return Fraction(1, min_compute_separating_cut(g))


def broadcast_root_lb(g: DiGraph, root: int) -> Fraction:
    """Eq (5) specialised to one source: T >= M / λ(root) with
    λ(root) = min_v F(root, v; G) — the exact bound the compiled broadcast
    schedule converges to as the chunk count grows."""
    from .schedule import broadcast_lambda
    return Fraction(1, broadcast_lambda(g, root))


def reduce_lb(g: DiGraph) -> Fraction:
    """Dual of eq (5): reduce is edge-reversed broadcast, so its bound is
    broadcast's on the transpose graph (equal for Eulerian G)."""
    return broadcast_lb(g.transpose())


def reduce_root_lb(g: DiGraph, root: int) -> Fraction:
    """Per-root reduce bound: M / min_v F(v, root; G) = broadcast_root_lb on
    the transpose graph."""
    return broadcast_root_lb(g.transpose(), root)


def allreduce_lb(g: DiGraph) -> Fraction:
    """max of eq (6) and eq (7), per unit M."""
    n = g.num_compute
    lb6 = Fraction(1, min_compute_separating_cut(g))
    best_single = max(single_node_cut(g, v) for v in sorted(g.compute))
    lb7 = Fraction(2 * (n - 1), n) / best_single
    return max(lb6, lb7)


def allgather_lb(g: DiGraph) -> Fraction:
    """Eq (1): runtime factor per unit M (the 1/N is folded in)."""
    return allgather_inv_xstar(g) / g.num_compute


#: memo for `alltoall_lb` — the bound is re-evaluated per simulate call and
#: the certified-cut sweep is hundreds of maxflows on the large fabrics
_A2A_LB_CACHE: Dict[str, Fraction] = {}

#: graphs up to this many total nodes get the exhaustive (exact over all
#: cuts) enumeration; larger ones the certified family
_A2A_ENUM_MAX_NODES = 16


def alltoall_lb(g: DiGraph) -> Fraction:
    """All-to-all runtime factor per unit M of per-node send buffer:
    ``max_S (1/N) · |S∩Vc| · (N−|S∩Vc|) / B+(S)`` — every source inside a
    cut S owes every destination outside it a distinct block of M/N bytes,
    all of which must cross S's egress capacity.

    Exhaustive over all cuts (hence exact) for graphs up to 16 nodes.
    Larger graphs maximize over a certified family — every single-node
    cut, every pairwise maxflow min-cut side and its complement, and
    every BFS-ball prefix cut from each compute seed — so the returned
    value is always a valid bound (each evaluated cut certifies it) and
    tight on fabrics whose bottleneck is a ball or a pairwise cut
    (rings, tori, circulants, switched clusters)."""
    key = g.fingerprint()
    hit = _A2A_LB_CACHE.get(key)
    if hit is not None:
        return hit
    n = g.num_compute
    if n < 2:
        raise ValueError("need >= 2 compute nodes")
    best = Fraction(0)

    def consider(nc: int, egress: int) -> None:
        nonlocal best
        if 0 < nc < n and egress > 0:
            val = Fraction(nc * (n - nc), n * egress)
            if val > best:
                best = val

    if g.num_nodes <= _A2A_ENUM_MAX_NODES:
        nodes = list(range(g.num_nodes))
        for r in range(1, g.num_nodes):
            for s in itertools.combinations(nodes, r):
                ss = set(s)
                consider(len(ss & g.compute), g.egress_set(ss))
    else:
        vc = sorted(g.compute)
        for v in vc:                       # |S∩Vc| = 1, minimal egress
            consider(1, single_node_cut(g, v))
        v0 = vc[0]
        all_nodes = set(range(g.num_nodes))
        for v in vc[1:]:
            for (s_node, t_node) in ((v0, v), (v, v0)):
                net = build_network(g)
                net.maxflow(s_node, t_node)
                side = set(net.min_cut_side(s_node))
                consider(len(side & g.compute), g.egress_set(side))
                comp = all_nodes - side
                consider(len(comp & g.compute), g.egress_set(comp))
        # BFS-ball prefix cuts, egress maintained incrementally: adding u
        # removes S→u capacity, adds u's out-capacity minus u→S
        out_adj: Dict[int, List[Tuple[int, int]]] = {}
        in_adj: Dict[int, List[Tuple[int, int]]] = {}
        out_cap: Dict[int, int] = {}
        for (a, b), c in g.cap.items():
            out_adj.setdefault(a, []).append((b, c))
            in_adj.setdefault(b, []).append((a, c))
            out_cap[a] = out_cap.get(a, 0) + c
        for seed in vc:
            order, seen = [seed], {seed}
            for u in order:
                for (w, _) in out_adj.get(u, ()):
                    if w not in seen:
                        seen.add(w)
                        order.append(w)
            ss: Set[int] = set()
            egress = nc = 0
            for u in order[:-1]:
                egress += out_cap.get(u, 0)
                egress -= sum(c for (w, c) in out_adj.get(u, ()) if w in ss)
                egress -= sum(c for (w, c) in in_adj.get(u, ()) if w in ss)
                ss.add(u)
                nc += u in g.compute
                consider(nc, egress)
    _A2A_LB_CACHE[key] = best
    return best


def rs_ag_allreduce_runtime(g: DiGraph) -> Fraction:
    """Runtime factor (per unit M) of optimal RS+AG allreduce: RS on G^T has
    the same optimum as AG on G (paper App. B), so RS+AG = 2 * (1)."""
    return 2 * allgather_lb(g)


def re_bc_allreduce_runtime(g: DiGraph) -> Fraction:
    """Runtime factor of optimal reduce+broadcast (Blink-style): reduce is
    reversed broadcast (same bound), so RE+BC = 2 * (5)."""
    return 2 * broadcast_lb(g)


# ---------------------------------------------------------------------- #
# Bottleneck-cut argmax + Theorem 19 (exponential — analysis/tests only)
# ---------------------------------------------------------------------- #

def brute_force_bottleneck_cut(g: DiGraph) -> Tuple[Set[int], Fraction]:
    """argmax_S |S∩Vc|/B+(S) by enumeration (guarded to small graphs)."""
    if g.num_nodes > 20:
        raise ValueError("bottleneck-cut enumeration limited to <= 20 nodes")
    best_cut: Set[int] = set()
    best = Fraction(0)
    nodes = list(range(g.num_nodes))
    for r in range(1, g.num_nodes + 1):
        for s in itertools.combinations(nodes, r):
            ss = set(s)
            if g.compute <= ss or not (ss & g.compute):
                continue
            out = g.egress_set(ss)
            if out == 0:
                continue
            val = Fraction(len(ss & g.compute), out)
            if val > best:
                best, best_cut = val, ss
    return best_cut, best


def theorem19_rs_ag_optimal(g: DiGraph) -> Optional[str]:
    """Check Theorem 19's sufficient conditions for RS+AG allreduce
    optimality.  Returns the satisfied condition name or None."""
    n = g.num_compute
    s_star, _ = brute_force_bottleneck_cut(g)
    nc = len(s_star & g.compute)
    if 2 * nc == n:
        return "(a) |S*∩Vc| = N/2"
    if nc == 1:
        (v_prime,) = tuple(s_star & g.compute)
        mine = single_node_cut(g, v_prime)
        best = max(single_node_cut(g, v) for v in sorted(g.compute))
        if mine == best:
            return "(b) singleton bottleneck with max single-node cut"
    return None
