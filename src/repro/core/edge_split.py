"""§2.2 Edge splitting (Algorithm 1) — remove switch nodes losslessly.

Repeatedly replaces a unit of capacity on ``(u, w), (w, t)`` (w a switch) by
a unit on the direct logical edge ``(u, t)`` while preserving

    min_{v∈Vc} F(s, v; D_k)  >=  |Vc| * k                      (Theorem 7)

Theorem 8 gives the *maximum* capacity M splittable in one shot via 2|Vc|
maxflows, which makes Algorithm 1 strongly polynomial (capacity-independent).

We also keep the paper's `routing` table: ``routing[(u,t)][w] = M`` records
that M units of the logical edge (u,t) physically traverse switch w.  After
tree construction, `expand_paths` recovers the concrete switch paths, which
the simulator uses to re-validate optimality on the *original* graph G.

Degenerate pairs (u == t) occur when surplus switch capacity must simply be
discarded (the split would create a self-loop).  Theorem 8's formula does not
cover that case, so we fall back to a direct monotone binary search on the
Theorem-5 oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .graph import DiGraph, Edge, validate_eulerian
from .maxflow import SourcedNetwork

PairPriority = Callable[[int, int, int], object]  # (u, w, t) -> sort key


@dataclasses.dataclass
class SplitResult:
    graph: DiGraph                       # D*: compute-only logical topology
    routing: Dict[Edge, Dict[int, int]]  # (u,t) -> {switch w: capacity via w}
    original: DiGraph                    # the input (scaled) switch topology
    k: int


class EdgeSplitError(RuntimeError):
    pass


# ---------------------------------------------------------------------- #
# Theorem 8: maximum splittable capacity for a concrete (e, f) pair
# ---------------------------------------------------------------------- #

def _dk_net(d: DiGraph, k: int,
            extra: Sequence[Tuple[int, int, int]] = ()) -> SourcedNetwork:
    """The D_k shape (super-source tied cap-k to every compute node) plus
    optional gadget edges, built once and re-probed in place."""
    return SourcedNetwork(d, {u: k for u in sorted(d.compute)}, extra=extra)


def max_split_capacity(d: DiGraph, k: int, u: int, w: int, t: int) -> int:
    """Theorem 8 / eq. (2): max M such that splitting (u,w),(w,t) by M keeps
    min_v F(s, v; D^ef_k) >= |Vc| k.  Requires u != t.

    One network per term serves every v: the per-sink ∞ gadget edge is a
    pre-installed capacity-0 edge toggled between sinks."""
    assert u != t, "degenerate pair handled by max_discard_capacity"
    c_uw = d.cap.get((u, w), 0)
    c_wt = d.cap.get((w, t), 0)
    bound = min(c_uw, c_wt)
    if bound == 0:
        return 0
    nk = d.num_compute * k
    inf = sum(d.cap.values()) + nk + bound + 1
    limit = nk + bound  # flows above this are non-binding
    s_id = d.num_nodes

    best = bound
    # term 3: min_v F(u, w; D̂_(u,w),v) - |Vc|k   with ∞ edges (u,s),(u,t),(v,w)
    net3 = _dk_net(d, k, extra=[(u, s_id, inf), (u, t, inf)])
    vw = {v: net3.add_probe_edge(v, w) for v in sorted(d.compute) if v != u}
    active = None
    for v in sorted(d.compute):
        if v == u:
            continue  # ∞ edge (v,w)=(u,w) makes F infinite — non-binding
        if active is not None:
            net3.net.set_edge_cap(active, 0)
        active = vw[v]
        net3.net.set_edge_cap(active, inf)
        f = net3.flow(u, w, limit=limit)
        best = min(best, f - nk)
        if best <= 0:
            return 0
        limit = min(limit, nk + best)
    # term 4: min_v F(w, t; D̂_(w,t),v) - |Vc|k   with ∞ edges (w,s),(u,t),(v,t)
    net4 = _dk_net(d, k, extra=[(w, s_id, inf), (u, t, inf)])
    vt = {v: net4.add_probe_edge(v, t) for v in sorted(d.compute) if v != t}
    active = None
    for v in sorted(d.compute):
        if active is not None:
            net4.net.set_edge_cap(active, 0)
            active = None
        if v != t:
            active = vt[v]
            net4.net.set_edge_cap(active, inf)
        f = net4.flow(w, t, limit=limit)
        best = min(best, f - nk)
        if best <= 0:
            return 0
        limit = min(limit, nk + best)
    return best


def _oracle_holds(d: DiGraph, k: int) -> bool:
    """min_v F(s, v; D_k) >= |Vc| k (Theorem 5 condition)."""
    return _dk_net(d, k).min_source_flow_at_least(sorted(d.compute),
                                                  d.num_compute * k)


def max_discard_capacity(d: DiGraph, k: int, u: int, w: int) -> int:
    """Degenerate split (u,w),(w,u): capacity is simply discarded.  Find the
    max M keeping the Theorem-5 oracle true, by monotone binary search over
    one shared network (probes rewrite the two edge capacities in place)."""
    c_uw = d.cap.get((u, w), 0)
    c_wu = d.cap.get((w, u), 0)
    bound = min(c_uw, c_wu)
    if bound == 0:
        return 0
    net = _dk_net(d, k)
    nk = d.num_compute * k
    sinks = sorted(d.compute)

    def ok(m: int) -> bool:
        net.set_cap(u, w, c_uw - m)
        net.set_cap(w, u, c_wu - m)
        return net.min_source_flow_at_least(sinks, nk)

    lo_ok, hi = 0, bound
    if ok(bound):
        return bound
    while hi - lo_ok > 1:
        mid = (lo_ok + hi) // 2
        if ok(mid):
            lo_ok = mid
        else:
            hi = mid
    return lo_ok


# ---------------------------------------------------------------------- #
# Rooted variant: preserve a demand-weighted tree-packing oracle
# ---------------------------------------------------------------------- #

def _oracle_holds_demands(d: DiGraph, demands: Dict[int, int]) -> bool:
    """Frank's rooted-packing condition: with a super-source s tied to each
    root u by demands[u] parallel arcs, min_v F(s, v; D) >= Σ demands —
    for broadcast ({root: λ}) this is exactly min_v F(root, v) >= λ."""
    net = SourcedNetwork(d, dict(sorted(demands.items())))
    return net.min_source_flow_at_least(sorted(d.compute),
                                        sum(demands.values()))


def max_split_capacity_rooted(d: DiGraph, demands: Dict[int, int],
                              u: int, w: int, t: int) -> int:
    """Max M such that splitting (u,w),(w,t) by M keeps the rooted oracle.

    Every cut's egress capacity is non-increasing in M under the split, so
    feasibility is monotone and a binary search on the oracle is exact (the
    closed form of Theorem 8 only covers the uniform all-roots case).  One
    shared network serves the whole search: each probe rewrites the three
    affected edge capacities in place."""
    c_uw = d.cap.get((u, w), 0)
    c_wt = d.cap.get((w, t), 0)
    bound = min(c_uw, c_wt)
    if bound == 0:
        return 0
    net = SourcedNetwork(d, dict(sorted(demands.items())))
    c_ut = d.cap.get((u, t), 0)
    total = sum(demands.values())
    sinks = sorted(d.compute)

    def ok(m: int) -> bool:
        net.set_cap(u, w, c_uw - m)
        net.set_cap(w, t, c_wt - m)
        if u != t:
            net.set_cap(u, t, c_ut + m)
        return net.min_source_flow_at_least(sinks, total)

    if ok(bound):
        return bound
    lo_ok, hi = 0, bound
    while hi - lo_ok > 1:
        mid = (lo_ok + hi) // 2
        if ok(mid):
            lo_ok = mid
        else:
            hi = mid
    return lo_ok


def remove_switches_rooted(d: DiGraph, demands: Dict[int, int],
                           pair_priority: Optional[PairPriority] = None,
                           verify: bool = False) -> SplitResult:
    """Algorithm-1 loop with the rooted (broadcast/reduce) oracle: split off
    all switches while preserving min_v F(s, v) >= Σ demands for the
    demand-weighted super-source — enough to pack `demands[u]` spanning
    out-trees at each root u afterwards (Frank).  Eulerian graphs always
    admit a complete splitting-off, so the greedy loop terminates."""
    validate_eulerian(d)
    k = sum(demands.values())
    return _isolate_switches(
        d, k,
        split_cap=lambda dd, u, w, t: max_split_capacity_rooted(
            dd, demands, u, w, t),
        discard_cap=lambda dd, t, w: max_split_capacity_rooted(
            dd, demands, t, w, t),
        pair_priority=pair_priority, verify=verify,
        oracle=lambda dd: _oracle_holds_demands(dd, demands))


# ---------------------------------------------------------------------- #
# Algorithm 1
# ---------------------------------------------------------------------- #

def remove_switches(d: DiGraph, k: int,
                    pair_priority: Optional[PairPriority] = None,
                    verify: bool = False) -> SplitResult:
    """Algorithm 1: split off all switch nodes of `d` (capacities already
    scaled to G({U b_e})), preserving the Theorem-5 tree-packing condition.

    pair_priority(u, w, t) orders ingress candidates per egress edge — the
    paper uses this hook (§2.2 example) to e.g. prefer cross-cluster pairs.
    """
    validate_eulerian(d)
    return _isolate_switches(
        d, k,
        split_cap=lambda dd, u, w, t: max_split_capacity(dd, k, u, w, t),
        discard_cap=lambda dd, t, w: max_discard_capacity(dd, k, t, w),
        pair_priority=pair_priority, verify=verify,
        oracle=lambda dd: _oracle_holds(dd, k))


def _isolate_switches(d: DiGraph, k: int,
                      split_cap, discard_cap,
                      pair_priority: Optional[PairPriority],
                      verify: bool, oracle) -> SplitResult:
    """Shared Algorithm-1 saturation loop, parameterised by the maximum-
    splittable-capacity oracles (Theorem-8 closed form for allgather,
    binary search for the rooted variants)."""
    original = d.copy()
    d = d.copy()
    routing: Dict[Edge, Dict[int, int]] = {}

    def apply_split(u: int, w: int, t: int, m: int) -> None:
        for e in ((u, w), (w, t)):
            d.cap[e] -= m
            if d.cap[e] == 0:
                del d.cap[e]
        if u != t:
            d.cap[(u, t)] = d.cap.get((u, t), 0) + m
            routing.setdefault((u, t), {})
            routing[(u, t)][w] = routing[(u, t)].get(w, 0) + m

    for w in sorted(d.switches):
        # saturate every egress edge of w in turn
        guard = 0
        while True:
            egress = sorted(t for (a, t) in d.cap if a == w)
            if not egress:
                break
            guard += 1
            if guard > 4 * (d.num_nodes ** 2 + len(d.cap) + 4):
                raise EdgeSplitError(f"no progress isolating switch {w}")
            progress = False
            for t in egress:
                if d.cap.get((w, t), 0) == 0:
                    continue
                ins = [a for (a, b) in d.cap if b == w and a != t]
                if pair_priority is not None:
                    ins.sort(key=lambda u: pair_priority(u, w, t))
                else:
                    ins.sort()
                for u in ins:
                    if d.cap.get((w, t), 0) == 0:
                        break
                    m = split_cap(d, u, w, t)
                    if m > 0:
                        apply_split(u, w, t, m)
                        progress = True
                # degenerate leftover: (t,w),(w,t) must be discarded
                if d.cap.get((w, t), 0) > 0 and d.cap.get((t, w), 0) > 0:
                    m = discard_cap(d, t, w)
                    if m > 0:
                        apply_split(t, w, t, m)
                        progress = True
            if not progress:
                raise EdgeSplitError(
                    f"stuck isolating switch {w}: residual "
                    f"{{e: c for e, c in d.cap.items() if w in e}}")
        # w should now be isolated
        residual = [(e, c) for e, c in d.cap.items() if w in e]
        if residual:
            raise EdgeSplitError(f"switch {w} not isolated: {residual}")

    star = DiGraph(d.num_nodes, d.compute, d.cap, original.name + "*")
    if verify:
        validate_eulerian(star)
        if not oracle(star):
            raise EdgeSplitError("edge splitting broke the packing oracle")
    return SplitResult(graph=star, routing=routing, original=original, k=k)


# ---------------------------------------------------------------------- #
# Path recovery: logical (u,t) capacity -> physical switch paths in G
# ---------------------------------------------------------------------- #

Path = Tuple[int, ...]


def expand_paths(res: SplitResult) -> Dict[Edge, List[Tuple[Path, int]]]:
    """Decompose every logical edge of D* into physical paths of G with
    integer capacities (a valid flow decomposition; conservation is exact)."""
    phys_pool: Dict[Edge, int] = dict(res.original.cap)
    via_pool: Dict[Edge, Dict[int, int]] = {
        e: dict(ws) for e, ws in res.routing.items()}

    def expand(a: int, b: int, amount: int) -> List[Tuple[Path, int]]:
        out: List[Tuple[Path, int]] = []
        take = min(amount, phys_pool.get((a, b), 0))
        if take:
            phys_pool[(a, b)] -= take
            out.append(((a, b), take))
            amount -= take
        for w in sorted(via_pool.get((a, b), {})):
            if amount == 0:
                break
            avail = via_pool[(a, b)][w]
            m = min(amount, avail)
            if m == 0:
                continue
            via_pool[(a, b)][w] -= m
            left = expand(a, w, m)
            right = expand(w, b, m)
            out.extend(_join(left, right))
            amount -= m
        if amount != 0:
            raise EdgeSplitError(
                f"path expansion under-supplied for ({a},{b}): short {amount}")
        return out

    result: Dict[Edge, List[Tuple[Path, int]]] = {}
    for (u, t), c in sorted(res.graph.cap.items()):
        result[(u, t)] = expand(u, t, c)
    return result


def _join(left: List[Tuple[Path, int]],
          right: List[Tuple[Path, int]]) -> List[Tuple[Path, int]]:
    """Splice a->..->w path pieces with w->..->b pieces, capacity-matched."""
    out: List[Tuple[Path, int]] = []
    li = ri = 0
    lpath, lcap = (left[0] if left else ((), 0))
    rpath, rcap = (right[0] if right else ((), 0))
    while li < len(left) and ri < len(right):
        m = min(lcap, rcap)
        out.append((lpath + rpath[1:], m))
        lcap -= m
        rcap -= m
        if lcap == 0:
            li += 1
            if li < len(left):
                lpath, lcap = left[li]
        if rcap == 0:
            ri += 1
            if ri < len(right):
                rpath, rcap = right[ri]
    return out


def trivial_split(d: DiGraph, k: int) -> SplitResult:
    """For already direct-connect topologies §2.2 is skippable."""
    if d.switches:
        raise ValueError("graph has switches; use remove_switches")
    return SplitResult(graph=d.copy(), routing={}, original=d.copy(), k=k)
