"""§2.2 Edge splitting (Algorithm 1) — remove switch nodes losslessly.

Repeatedly replaces a unit of capacity on ``(u, w), (w, t)`` (w a switch) by
a unit on the direct logical edge ``(u, t)`` while preserving

    min_{v∈Vc} F(s, v; D_k)  >=  |Vc| * k                      (Theorem 7)

Theorem 8 gives the *maximum* capacity M splittable in one shot via 2|Vc|
maxflows, which makes Algorithm 1 strongly polynomial (capacity-independent).

Oracle engine: one incremental prober serves a whole `remove_switches`
run.  The Theorem-8 term scans share a single D_k `SourcedNetwork` (gadget
edges are pre-installed capacity-0 parallels toggled in place — two fresh
network builds per (u, w, t) pair became zero), remember the last *binding*
sink per switch and probe it first (the running minimum tightens the flow
`limit` immediately, so the remaining probes early-exit almost at once; the
final minimum is order-independent), and the degenerate-discard / rooted
binary searches descend on warm-started per-sink flows
(`min_source_flow_at_least(..., warm=True)`) instead of recomputing each
probe from a cold residual network.

We also keep the paper's `routing` table: ``routing[(u,t)][w] = M`` records
that M units of the logical edge (u,t) physically traverse switch w.  After
tree construction, `expand_paths` recovers the concrete switch paths, which
the simulator uses to re-validate optimality on the *original* graph G.

Degenerate pairs (u == t) occur when surplus switch capacity must simply be
discarded (the split would create a self-loop).  Theorem 8's formula does not
cover that case, so we fall back to a direct monotone binary search on the
Theorem-5 oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .graph import DiGraph, Edge, validate_eulerian
from .maxflow import SourcedNetwork

PairPriority = Callable[[int, int, int], object]  # (u, w, t) -> sort key


@dataclasses.dataclass
class SplitResult:
    graph: DiGraph                       # D*: compute-only logical topology
    routing: Dict[Edge, Dict[int, int]]  # (u,t) -> {switch w: capacity via w}
    original: DiGraph                    # the input (scaled) switch topology
    k: int


class EdgeSplitError(RuntimeError):
    pass


# ---------------------------------------------------------------------- #
# Theorem 8: maximum splittable capacity (shared incremental prober)
# ---------------------------------------------------------------------- #

def _dk_net(d: DiGraph, k: int,
            extra: Sequence[Tuple[int, int, int]] = ()) -> SourcedNetwork:
    """The D_k shape (super-source tied cap-k to every compute node) plus
    optional gadget edges, built once and re-probed in place."""
    return SourcedNetwork(d, {u: k for u in sorted(d.compute)}, extra=extra)


class _TheoremEightProber:
    """One D_k oracle network serving every Theorem-8 term scan *and* every
    degenerate-discard binary search of an Algorithm-1 run.

    Gadget edges (the per-term ∞ edges and per-sink probe edges) are
    capacity-0 parallels added lazily and toggled in place; `sync` mirrors
    each applied split's 3 capacity changes into the network.  The ∞
    stand-in only needs to exceed every flow limit ever probed; capacity
    never enters the system after construction (splits move or discard it),
    so one value sized from the initial graph stays valid for the whole
    run — the computed M is identical for any sufficiently large value.
    """

    def __init__(self, d: DiGraph, k: int):
        self.d = d
        self.k = k
        self.nk = d.num_compute * k
        self.net = _dk_net(d, k)
        self.inf = 2 * sum(d.cap.values()) + self.nk + 1
        self.sinks = sorted(d.compute)
        # keyed (a, b, tag): a term's base ∞ edge and a per-sink probe edge
        # over the same (a, b) stay separate parallels, as in the paper's D̂
        self._gadget: Dict[Tuple[int, int, str], int] = {}
        self._armed: List[int] = []
        self._hot3: Dict[int, int] = {}   # switch w -> last binding sink
        self._hot4: Dict[int, int] = {}

    # -- gadget plumbing ------------------------------------------------ #

    def _arm(self, a: int, b: int, cap: int, tag: str = "base") -> int:
        eid = self._gadget.get((a, b, tag))
        if eid is None:
            eid = self.net.add_probe_edge(a, b)
            self._gadget[(a, b, tag)] = eid
        self.net.set_cap_id(eid, cap)
        self._armed.append(eid)
        return eid

    def _disarm(self) -> None:
        for eid in self._armed:
            self.net.set_cap_id(eid, 0)
        self._armed.clear()

    def sync(self, edges: Sequence[Edge]) -> None:
        """Mirror the graph capacities of `edges` (changed by an applied
        split) into the oracle network."""
        for e in edges:
            if e[0] != e[1]:
                self.net.set_cap(*e, self.d.cap.get(e, 0))

    @staticmethod
    def _hot_first(order: List[int], hot: Optional[int]) -> List[int]:
        if hot is not None and hot in order and order[0] != hot:
            order.remove(hot)
            order.insert(0, hot)
        return order

    # -- Theorem 8 / eq. (2) -------------------------------------------- #

    def split_cap(self, u: int, w: int, t: int) -> int:
        """Theorem 8 / eq. (2): max M such that splitting (u,w),(w,t) by M
        keeps min_v F(s, v; D^ef_k) >= |Vc| k.  Requires u != t.

        Each term's minimum is taken sink-adaptively: the last binding sink
        of this switch is probed first, so `limit` collapses to the final
        minimum immediately and later probes early-exit (the minimum itself
        is order-independent)."""
        assert u != t, "degenerate pair handled by discard_cap"
        d = self.d
        c_uw = d.cap.get((u, w), 0)
        c_wt = d.cap.get((w, t), 0)
        bound = min(c_uw, c_wt)
        if bound == 0:
            return 0
        nk = self.nk
        limit = nk + bound  # flows above this are non-binding
        best = bound

        # term 3: min_v F(u, w; D̂_(u,w),v) - |Vc|k
        #         with ∞ edges (u,s),(u,t),(v,w)
        # (∞ edge (v,w)=(u,w) would make F infinite, so v == u is skipped)
        best = self._term_min(
            src=u, snk=w, base=((u, self.net.s), (u, t)),
            order=self._hot_first([v for v in self.sinks if v != u],
                                  self._hot3.get(w)),
            probe_head=w, skip_probe=None, best=best, hot=self._hot3, w=w)
        if best <= 0:
            return 0

        # term 4: min_v F(w, t; D̂_(w,t),v) - |Vc|k
        #         with ∞ edges (w,s),(u,t),(v,t)
        # (v == t is probed with no gadget edge: plain F(w, t))
        best = self._term_min(
            src=w, snk=t, base=((w, self.net.s), (u, t)),
            order=self._hot_first(list(self.sinks), self._hot4.get(w)),
            probe_head=t, skip_probe=t, best=best, hot=self._hot4, w=w)
        return max(best, 0)

    def _term_min(self, src: int, snk: int, base, order, probe_head: int,
                  skip_probe: Optional[int], best: int,
                  hot: Dict[int, int], w: int) -> int:
        """One eq.-(2) term:  min_v F(src, snk; D̂ with (v, probe_head) ∞
        probe edge) − |Vc|k,  folded into the running `best`.

        The flow is carried *across* sinks: swapping the probe edge drains
        the outgoing probe's flow (flow-preserving decrease) and re-augments
        only the delta, instead of recomputing the nk-unit base flow per
        sink.  The probe `limit` tracks nk + best; a carried flow value at
        or above the limit means this v is non-binding (f = min(F_v, limit)
        of the cold scan), below it the augmented value is the exact F_v —
        identical results to per-sink cold maxflows, in any probe order."""
        net, nk, inf = self.net, self.nk, self.inf
        self._disarm()
        for (a, b) in base:
            self._arm(a, b, inf)
        probe = None
        value = None
        limit = nk + best
        for v in order:
            if probe is not None:
                value -= net.decrease_cap_id(probe, 0, src, snk)
                probe = None
            if v != skip_probe:
                eid = self._gadget.get((v, probe_head, "probe"))
                if eid is None:
                    eid = self.net.add_probe_edge(v, probe_head)
                    self._gadget[(v, probe_head, "probe")] = eid
                self._armed.append(eid)
                probe = eid
            if value is None:
                if probe is not None:
                    net.set_cap_id(probe, inf)
                value = net.flow(src, snk, limit=limit)
            else:
                if probe is not None:
                    net.increase_cap_id(probe, inf)
                if value < limit:
                    value += net.net.maxflow(src, snk, limit=limit - value)
            if value < limit:            # binding: value is the exact F_v
                best = value - nk
                hot[w] = v
                if best <= 0:
                    self._disarm()
                    return best
                limit = nk + best
        self._disarm()
        return best

    # -- degenerate discard --------------------------------------------- #

    def discard_cap(self, u: int, w: int) -> int:
        """Degenerate split (u,w),(w,u): capacity is simply discarded.  Max
        M keeping the Theorem-5 oracle true, by monotone binary search over
        the shared network with warm-started per-sink flows (each probe
        only moves the two rewritten capacities and re-augments)."""
        d = self.d
        c_uw = d.cap.get((u, w), 0)
        c_wu = d.cap.get((w, u), 0)
        bound = min(c_uw, c_wu)
        if bound == 0:
            return 0
        self._disarm()
        net, nk, sinks = self.net, self.nk, self.sinks

        def ok(m: int) -> bool:
            net.set_cap(u, w, c_uw - m)
            net.set_cap(w, u, c_wu - m)
            return net.min_source_flow_at_least(sinks, nk, warm=True)

        try:
            if ok(bound):
                return bound
            lo_ok, hi = 0, bound
            while hi - lo_ok > 1:
                mid = (lo_ok + hi) // 2
                if ok(mid):
                    lo_ok = mid
                else:
                    hi = mid
            return lo_ok
        finally:
            net.set_cap(u, w, c_uw)
            net.set_cap(w, u, c_wu)


def max_split_capacity(d: DiGraph, k: int, u: int, w: int, t: int) -> int:
    """One-shot Theorem-8 maximum (fresh prober; Algorithm 1 keeps a shared
    prober across its whole run instead)."""
    return _TheoremEightProber(d, k).split_cap(u, w, t)


def max_discard_capacity(d: DiGraph, k: int, u: int, w: int) -> int:
    """One-shot degenerate-discard maximum (fresh prober)."""
    return _TheoremEightProber(d, k).discard_cap(u, w)


def _oracle_holds(d: DiGraph, k: int) -> bool:
    """min_v F(s, v; D_k) >= |Vc| k (Theorem 5 condition)."""
    return _dk_net(d, k).min_source_flow_at_least(sorted(d.compute),
                                                  d.num_compute * k)


# ---------------------------------------------------------------------- #
# Rooted variant: preserve a demand-weighted tree-packing oracle
# ---------------------------------------------------------------------- #

def _oracle_holds_demands(d: DiGraph, demands: Dict[int, int]) -> bool:
    """Frank's rooted-packing condition: with a super-source s tied to each
    root u by demands[u] parallel arcs, min_v F(s, v; D) >= Σ demands —
    for broadcast ({root: λ}) this is exactly min_v F(root, v) >= λ."""
    net = SourcedNetwork(d, dict(sorted(demands.items())))
    return net.min_source_flow_at_least(sorted(d.compute),
                                        sum(demands.values()))


class _RootedProber:
    """The rooted (broadcast/reduce) analogue of `_TheoremEightProber`: one
    demand-weighted `SourcedNetwork` serves every binary search of a
    `remove_switches_rooted` run, with warm-started per-sink flows."""

    def __init__(self, d: DiGraph, demands: Dict[int, int]):
        self.d = d
        self.total = sum(demands.values())
        self.net = SourcedNetwork(d, dict(sorted(demands.items())))
        self.sinks = sorted(d.compute)

    def sync(self, edges: Sequence[Edge]) -> None:
        for e in edges:
            if e[0] != e[1]:
                self.net.set_cap(*e, self.d.cap.get(e, 0))

    def split_cap(self, u: int, w: int, t: int) -> int:
        """Max M such that splitting (u,w),(w,t) by M keeps the rooted
        oracle.  Every cut's egress capacity is non-increasing in M under
        the split, so feasibility is monotone and a binary search on the
        oracle is exact (the closed form of Theorem 8 only covers the
        uniform all-roots case).  Each probe rewrites the three affected
        capacities and re-augments the warm per-sink flows."""
        d, net = self.d, self.net
        c_uw = d.cap.get((u, w), 0)
        c_wt = d.cap.get((w, t), 0)
        bound = min(c_uw, c_wt)
        if bound == 0:
            return 0
        c_ut = d.cap.get((u, t), 0)
        total, sinks = self.total, self.sinks

        def ok(m: int) -> bool:
            net.set_cap(u, w, c_uw - m)
            net.set_cap(w, t, c_wt - m)
            if u != t:
                net.set_cap(u, t, c_ut + m)
            return net.min_source_flow_at_least(sinks, total, warm=True)

        try:
            if ok(bound):
                return bound
            lo_ok, hi = 0, bound
            while hi - lo_ok > 1:
                mid = (lo_ok + hi) // 2
                if ok(mid):
                    lo_ok = mid
                else:
                    hi = mid
            return lo_ok
        finally:
            net.set_cap(u, w, c_uw)
            net.set_cap(w, t, c_wt)
            if u != t:
                net.set_cap(u, t, c_ut)

    def discard_cap(self, t: int, w: int) -> int:
        return self.split_cap(t, w, t)


def max_split_capacity_rooted(d: DiGraph, demands: Dict[int, int],
                              u: int, w: int, t: int) -> int:
    """One-shot rooted maximum (fresh prober; Algorithm 1 keeps a shared
    warm prober across its whole run instead)."""
    return _RootedProber(d, demands).split_cap(u, w, t)


def remove_switches_rooted(d: DiGraph, demands: Dict[int, int],
                           pair_priority: Optional[PairPriority] = None,
                           verify: bool = False) -> SplitResult:
    """Algorithm-1 loop with the rooted (broadcast/reduce) oracle: split off
    all switches while preserving min_v F(s, v) >= Σ demands for the
    demand-weighted super-source — enough to pack `demands[u]` spanning
    out-trees at each root u afterwards (Frank).  Eulerian graphs always
    admit a complete splitting-off, so the greedy loop terminates."""
    validate_eulerian(d)
    k = sum(demands.values())
    return _isolate_switches(
        d, k,
        prober_factory=lambda dd: _RootedProber(dd, demands),
        pair_priority=pair_priority, verify=verify,
        oracle=lambda dd: _oracle_holds_demands(dd, demands))


# ---------------------------------------------------------------------- #
# Algorithm 1
# ---------------------------------------------------------------------- #

def remove_switches(d: DiGraph, k: int,
                    pair_priority: Optional[PairPriority] = None,
                    verify: bool = False) -> SplitResult:
    """Algorithm 1: split off all switch nodes of `d` (capacities already
    scaled to G({U b_e})), preserving the Theorem-5 tree-packing condition.

    pair_priority(u, w, t) orders ingress candidates per egress edge — the
    paper uses this hook (§2.2 example) to e.g. prefer cross-cluster pairs.
    """
    validate_eulerian(d)
    return _isolate_switches(
        d, k,
        prober_factory=lambda dd: _TheoremEightProber(dd, k),
        pair_priority=pair_priority, verify=verify,
        oracle=lambda dd: _oracle_holds(dd, k))


def _isolate_switches(d: DiGraph, k: int,
                      prober_factory,
                      pair_priority: Optional[PairPriority],
                      verify: bool, oracle) -> SplitResult:
    """Shared Algorithm-1 saturation loop, parameterised by the maximum-
    splittable-capacity prober (Theorem-8 closed form for allgather,
    warm binary search for the rooted variants).  One prober — and its
    incremental oracle network — lives for the whole run; applied splits
    are mirrored into it instead of triggering rebuilds."""
    original = d.copy()
    d = d.copy()
    prober = prober_factory(d)
    routing: Dict[Edge, Dict[int, int]] = {}

    def apply_split(u: int, w: int, t: int, m: int) -> None:
        for e in ((u, w), (w, t)):
            d.cap[e] -= m
            if d.cap[e] == 0:
                del d.cap[e]
        if u != t:
            d.cap[(u, t)] = d.cap.get((u, t), 0) + m
            routing.setdefault((u, t), {})
            routing[(u, t)][w] = routing[(u, t)].get(w, 0) + m
        prober.sync(((u, w), (w, t), (u, t)))

    for w in sorted(d.switches):
        # saturate every egress edge of w in turn
        guard = 0
        while True:
            egress = sorted(t for (a, t) in d.cap if a == w)
            if not egress:
                break
            guard += 1
            if guard > 4 * (d.num_nodes ** 2 + len(d.cap) + 4):
                raise EdgeSplitError(f"no progress isolating switch {w}")
            progress = False
            for t in egress:
                if d.cap.get((w, t), 0) == 0:
                    continue
                ins = [a for (a, b) in d.cap if b == w and a != t]
                if pair_priority is not None:
                    ins.sort(key=lambda u: pair_priority(u, w, t))
                else:
                    ins.sort()
                for u in ins:
                    if d.cap.get((w, t), 0) == 0:
                        break
                    m = prober.split_cap(u, w, t)
                    if m > 0:
                        apply_split(u, w, t, m)
                        progress = True
                # degenerate leftover: (t,w),(w,t) must be discarded
                if d.cap.get((w, t), 0) > 0 and d.cap.get((t, w), 0) > 0:
                    m = prober.discard_cap(t, w)
                    if m > 0:
                        apply_split(t, w, t, m)
                        progress = True
            if not progress:
                raise EdgeSplitError(
                    f"stuck isolating switch {w}: residual "
                    f"{{e: c for e, c in d.cap.items() if w in e}}")
        # w should now be isolated
        residual = [(e, c) for e, c in d.cap.items() if w in e]
        if residual:
            raise EdgeSplitError(f"switch {w} not isolated: {residual}")

    star = DiGraph(d.num_nodes, d.compute, d.cap, original.name + "*")
    if verify:
        validate_eulerian(star)
        if not oracle(star):
            raise EdgeSplitError("edge splitting broke the packing oracle")
    return SplitResult(graph=star, routing=routing, original=original, k=k)


# ---------------------------------------------------------------------- #
# Path recovery: logical (u,t) capacity -> physical switch paths in G
# ---------------------------------------------------------------------- #

Path = Tuple[int, ...]


def expand_paths(res: SplitResult) -> Dict[Edge, List[Tuple[Path, int]]]:
    """Decompose every logical edge of D* into physical paths of G with
    integer capacities (a valid flow decomposition; conservation is exact)."""
    phys_pool: Dict[Edge, int] = dict(res.original.cap)
    via_pool: Dict[Edge, Dict[int, int]] = {
        e: dict(ws) for e, ws in res.routing.items()}

    def expand(a: int, b: int, amount: int) -> List[Tuple[Path, int]]:
        out: List[Tuple[Path, int]] = []
        take = min(amount, phys_pool.get((a, b), 0))
        if take:
            phys_pool[(a, b)] -= take
            out.append(((a, b), take))
            amount -= take
        for w in sorted(via_pool.get((a, b), {})):
            if amount == 0:
                break
            avail = via_pool[(a, b)][w]
            m = min(amount, avail)
            if m == 0:
                continue
            via_pool[(a, b)][w] -= m
            left = expand(a, w, m)
            right = expand(w, b, m)
            out.extend(_join(left, right))
            amount -= m
        if amount != 0:
            raise EdgeSplitError(
                f"path expansion under-supplied for ({a},{b}): short {amount}")
        return out

    result: Dict[Edge, List[Tuple[Path, int]]] = {}
    for (u, t), c in sorted(res.graph.cap.items()):
        result[(u, t)] = expand(u, t, c)
    return result


def _join(left: List[Tuple[Path, int]],
          right: List[Tuple[Path, int]]) -> List[Tuple[Path, int]]:
    """Splice a->..->w path pieces with w->..->b pieces, capacity-matched."""
    out: List[Tuple[Path, int]] = []
    li = ri = 0
    lpath, lcap = (left[0] if left else ((), 0))
    rpath, rcap = (right[0] if right else ((), 0))
    while li < len(left) and ri < len(right):
        m = min(lcap, rcap)
        out.append((lpath + rpath[1:], m))
        lcap -= m
        rcap -= m
        if lcap == 0:
            li += 1
            if li < len(left):
                lpath, lcap = left[li]
        if rcap == 0:
            ri += 1
            if ri < len(right):
                rpath, rcap = right[ri]
    return out


def trivial_split(d: DiGraph, k: int) -> SplitResult:
    """For already direct-connect topologies §2.2 is skippable."""
    if d.switches:
        raise ValueError("graph has switches; use remove_switches")
    return SplitResult(graph=d.copy(), routing={}, original=d.copy(), k=k)
