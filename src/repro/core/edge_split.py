"""§2.2 Edge splitting (Algorithm 1) — remove switch nodes losslessly.

Repeatedly replaces a unit of capacity on ``(u, w), (w, t)`` (w a switch) by
a unit on the direct logical edge ``(u, t)`` while preserving

    min_{v∈Vc} F(s, v; D_k)  >=  |Vc| * k                      (Theorem 7)

Theorem 8 gives the *maximum* capacity M splittable in one shot via 2|Vc|
maxflows, which makes Algorithm 1 strongly polynomial (capacity-independent).

Oracle engine: one incremental prober serves a whole `remove_switches`
run.  The Theorem-8 term scans share a single D_k `SourcedNetwork` (gadget
edges are pre-installed capacity-0 parallels toggled in place — two fresh
network builds per (u, w, t) pair became zero), remember the last *binding*
sink per switch and probe it first (the running minimum tightens the flow
`limit` immediately, so the remaining probes early-exit almost at once; the
final minimum is order-independent), and the degenerate-discard / rooted
binary searches descend on warm-started per-sink flows
(`min_source_flow_at_least(..., warm=True)`) instead of recomputing each
probe from a cold residual network.

We also keep the paper's `routing` table: ``routing[(u,t)][w] = M`` records
that M units of the logical edge (u,t) physically traverse switch w.  After
tree construction, `expand_paths` recovers the concrete switch paths, which
the simulator uses to re-validate optimality on the *original* graph G.

Degenerate pairs (u == t) occur when surplus switch capacity must simply be
discarded (the split would create a self-loop).  Theorem 8's formula does not
cover that case, so we fall back to a direct monotone binary search on the
Theorem-5 oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .graph import DiGraph, Edge, validate_eulerian
from .maxflow import SourcedNetwork

PairPriority = Callable[[int, int, int], object]  # (u, w, t) -> sort key


@dataclasses.dataclass
class SplitResult:
    graph: DiGraph                       # D*: compute-only logical topology
    routing: Dict[Edge, Dict[int, int]]  # (u,t) -> {switch w: capacity via w}
    original: DiGraph                    # the input (scaled) switch topology
    k: int


class EdgeSplitError(RuntimeError):
    pass


# ---------------------------------------------------------------------- #
# Theorem 8: maximum splittable capacity (shared incremental prober)
# ---------------------------------------------------------------------- #

def _dk_net(d: DiGraph, k: int,
            extra: Sequence[Tuple[int, int, int]] = ()) -> SourcedNetwork:
    """The D_k shape (super-source tied cap-k to every compute node) plus
    optional gadget edges, built once and re-probed in place."""
    return SourcedNetwork(d, {u: k for u in sorted(d.compute)}, extra=extra)


class _TheoremEightProber:
    """One D_k oracle network serving every Theorem-8 term scan *and* every
    degenerate-discard binary search of an Algorithm-1 run.

    Gadget edges (the per-term ∞ edges and per-sink probe edges) are
    capacity-0 parallels added lazily and toggled in place; `sync` mirrors
    each applied split's 3 capacity changes into the network.  The ∞
    stand-in only needs to exceed every flow limit ever probed; capacity
    never enters the system after construction (splits move or discard it),
    so one value sized from the initial graph stays valid for the whole
    run — the computed M is identical for any sufficiently large value.
    """

    def __init__(self, d: DiGraph, k: int):
        self.d = d
        self.k = k
        self.nk = d.num_compute * k
        self.net = _dk_net(d, k)
        self.inf = 2 * sum(d.cap.values()) + self.nk + 1
        self.sinks = sorted(d.compute)
        # keyed (a, b, tag): a term's base ∞ edge and a per-sink probe edge
        # over the same (a, b) stay separate parallels, as in the paper's D̂
        self._gadget: Dict[Tuple[int, int, str], int] = {}
        self._armed: List[int] = []
        self._hot3: Dict[int, int] = {}   # switch w -> last binding sink
        self._hot4: Dict[int, int] = {}
        # (src, snk, probe_head) -> flow snapshot: each eq.-(2) term's base
        # flow is warm-restarted when the term is revisited (later rounds of
        # the saturation loop, or a transplanted repair run)
        self._twarm: Dict[Tuple[int, int, int],
                          Tuple[List[int], int, List[int]]] = {}

    @classmethod
    def transplant(cls, base: "_TheoremEightProber", d: DiGraph,
                   k: int) -> "_TheoremEightProber":
        """A prober for graph `d` (typically a degraded rescale of the base
        run's input) that inherits the base run's oracle network, warm flow
        snapshots, and binding-sink history instead of starting cold.  Every
        capacity is rewritten to `d`'s value through the target-tracking
        setters, so the first warm probe of each flow drains/augments
        exactly the capacity delta between the runs — verdicts are
        unchanged (the warm engine is exact), only the work shrinks."""
        self = cls.__new__(cls)
        self.d = d
        self.k = k
        self.nk = d.num_compute * k
        self.net = base.net.clone(g=d)
        self.inf = max(base.inf, 2 * sum(d.cap.values()) + self.nk + 1)
        self.sinks = sorted(d.compute)
        self._gadget = dict(base._gadget)
        self._armed = []
        self._hot3 = dict(base._hot3)
        self._hot4 = dict(base._hot4)
        # snapshot tuples are never mutated (warm_flow replaces entries
        # wholesale), so sharing them with the base prober is safe
        self._twarm = dict(base._twarm)
        net = self.net
        for e, eid in net.eid.items():
            net.set_cap_id(eid, d.cap.get(e, 0))
        for eid in self._gadget.values():
            net.set_cap_id(eid, 0)
        for u, eid in net.src_eid.items():
            net.set_cap_id(eid, k)
        return self

    # -- gadget plumbing ------------------------------------------------ #

    def _arm(self, a: int, b: int, cap: int, tag: str = "base") -> int:
        eid = self._gadget.get((a, b, tag))
        if eid is None:
            eid = self.net.add_probe_edge(a, b)
            self._gadget[(a, b, tag)] = eid
        self.net.set_cap_id(eid, cap)
        self._armed.append(eid)
        return eid

    def _disarm(self) -> None:
        for eid in self._armed:
            self.net.set_cap_id(eid, 0)
        self._armed.clear()

    def sync(self, edges: Sequence[Edge]) -> None:
        """Mirror the graph capacities of `edges` (changed by an applied
        split) into the oracle network."""
        for e in edges:
            if e[0] != e[1]:
                self.net.set_cap(*e, self.d.cap.get(e, 0))

    @staticmethod
    def _hot_first(order: List[int], hot: Optional[int]) -> List[int]:
        if hot is not None and hot in order and order[0] != hot:
            order.remove(hot)
            order.insert(0, hot)
        return order

    # -- Theorem 8 / eq. (2) -------------------------------------------- #

    def split_cap(self, u: int, w: int, t: int,
                  expect: Optional[int] = None) -> int:
        """Theorem 8 / eq. (2): max M such that splitting (u,w),(w,t) by M
        keeps min_v F(s, v; D^ef_k) >= |Vc| k.  Requires u != t.

        Each term's minimum is taken sink-adaptively: the last binding sink
        of this switch is probed first, so `limit` collapses to the final
        minimum immediately and later probes early-exit (the minimum itself
        is order-independent).

        `expect` is a caller-guaranteed upper bound on the answer (replay
        under capacity domination passes the base run's value): the running
        minimum starts there, so every probe runs against the tightest
        possible flow limit.  Results at the clamp are exact because the
        true value cannot exceed it."""
        assert u != t, "degenerate pair handled by discard_cap"
        d = self.d
        c_uw = d.cap.get((u, w), 0)
        c_wt = d.cap.get((w, t), 0)
        bound = min(c_uw, c_wt)
        if expect is not None:
            bound = min(bound, expect)
        if bound <= 0:
            return 0
        nk = self.nk
        limit = nk + bound  # flows above this are non-binding
        best = bound

        # term 3: min_v F(u, w; D̂_(u,w),v) - |Vc|k
        #         with ∞ edges (u,s),(u,t),(v,w)
        # (∞ edge (v,w)=(u,w) would make F infinite, so v == u is skipped)
        best = self._term_min(
            src=u, snk=w, base=((u, self.net.s), (u, t)),
            order=self._hot_first([v for v in self.sinks if v != u],
                                  self._hot3.get(w)),
            probe_head=w, skip_probe=None, best=best, hot=self._hot3, w=w)
        if best <= 0:
            return 0

        # term 4: min_v F(w, t; D̂_(w,t),v) - |Vc|k
        #         with ∞ edges (w,s),(u,t),(v,t)
        # (v == t is probed with no gadget edge: plain F(w, t))
        best = self._term_min(
            src=w, snk=t, base=((w, self.net.s), (u, t)),
            order=self._hot_first(list(self.sinks), self._hot4.get(w)),
            probe_head=t, skip_probe=t, best=best, hot=self._hot4, w=w)
        return max(best, 0)

    def _term_min(self, src: int, snk: int, base, order, probe_head: int,
                  skip_probe: Optional[int], best: int,
                  hot: Dict[int, int], w: int) -> int:
        """One eq.-(2) term:  min_v F(src, snk; D̂ with (v, probe_head) ∞
        probe edge) − |Vc|k,  folded into the running `best`.

        The flow is carried *across* sinks: swapping the probe edge drains
        the outgoing probe's flow (flow-preserving decrease) and re-augments
        only the delta, instead of recomputing the nk-unit base flow per
        sink.  The probe `limit` tracks nk + best; a carried flow value at
        or above the limit means this v is non-binding (f = min(F_v, limit)
        of the cold scan), below it the augmented value is the exact F_v —
        identical results to per-sink cold maxflows, in any probe order."""
        net, nk, inf = self.net, self.nk, self.inf
        self._disarm()
        for (a, b) in base:
            self._arm(a, b, inf)
        probe = None
        value = None
        limit = nk + best
        for v in order:
            if probe is not None:
                value -= net.decrease_cap_id(probe, 0, src, snk)
                probe = None
            if v != skip_probe:
                eid = self._gadget.get((v, probe_head, "probe"))
                if eid is None:
                    eid = self.net.add_probe_edge(v, probe_head)
                    self._gadget[(v, probe_head, "probe")] = eid
                self._armed.append(eid)
                probe = eid
            if value is None:
                if probe is not None:
                    net.set_cap_id(probe, inf)
                value = net.warm_flow(self._twarm, (src, snk, probe_head),
                                      src, snk, limit)
            else:
                if probe is not None:
                    net.increase_cap_id(probe, inf)
                if value < limit:
                    value += net.net.maxflow(src, snk, limit=limit - value)
            if value < limit:            # binding: value is the exact F_v
                best = value - nk
                hot[w] = v
                if best <= 0:
                    self._disarm()
                    return best
                limit = nk + best
        self._disarm()
        return best

    # -- degenerate discard --------------------------------------------- #

    def discard_cap(self, u: int, w: int,
                    expect: Optional[int] = None) -> int:
        """Degenerate split (u,w),(w,u): capacity is simply discarded.  Max
        M keeping the Theorem-5 oracle true, by monotone binary search over
        the shared network with warm-started per-sink flows (each probe
        only moves the two rewritten capacities and re-augments).

        `expect` is a caller-guaranteed upper bound on the answer (replay
        under capacity domination): one feasibility check at it decides the
        whole search, and on failure the search resumes below it."""
        d = self.d
        c_uw = d.cap.get((u, w), 0)
        c_wu = d.cap.get((w, u), 0)
        bound = min(c_uw, c_wu)
        if expect is not None:
            bound = min(bound, expect)
        if bound <= 0:
            return 0
        self._disarm()
        net, nk, sinks = self.net, self.nk, self.sinks

        def ok(m: int) -> bool:
            net.set_cap(u, w, c_uw - m)
            net.set_cap(w, u, c_wu - m)
            return net.min_source_flow_at_least(sinks, nk, warm=True)

        try:
            if ok(bound):
                return bound
            lo_ok, hi = 0, bound
            while hi - lo_ok > 1:
                mid = (lo_ok + hi) // 2
                if ok(mid):
                    lo_ok = mid
                else:
                    hi = mid
            return lo_ok
        finally:
            net.set_cap(u, w, c_uw)
            net.set_cap(w, u, c_wu)


def max_split_capacity(d: DiGraph, k: int, u: int, w: int, t: int) -> int:
    """One-shot Theorem-8 maximum (fresh prober; Algorithm 1 keeps a shared
    prober across its whole run instead)."""
    return _TheoremEightProber(d, k).split_cap(u, w, t)


def max_discard_capacity(d: DiGraph, k: int, u: int, w: int) -> int:
    """One-shot degenerate-discard maximum (fresh prober)."""
    return _TheoremEightProber(d, k).discard_cap(u, w)


def _oracle_holds(d: DiGraph, k: int) -> bool:
    """min_v F(s, v; D_k) >= |Vc| k (Theorem 5 condition)."""
    return _dk_net(d, k).min_source_flow_at_least(sorted(d.compute),
                                                  d.num_compute * k)


# ---------------------------------------------------------------------- #
# Rooted variant: preserve a demand-weighted tree-packing oracle
# ---------------------------------------------------------------------- #

def _oracle_holds_demands(d: DiGraph, demands: Dict[int, int]) -> bool:
    """Frank's rooted-packing condition: with a super-source s tied to each
    root u by demands[u] parallel arcs, min_v F(s, v; D) >= Σ demands —
    for broadcast ({root: λ}) this is exactly min_v F(root, v) >= λ."""
    net = SourcedNetwork(d, dict(sorted(demands.items())))
    return net.min_source_flow_at_least(sorted(d.compute),
                                        sum(demands.values()))


class _RootedProber:
    """The rooted (broadcast/reduce) analogue of `_TheoremEightProber`: one
    demand-weighted `SourcedNetwork` serves every binary search of a
    `remove_switches_rooted` run, with warm-started per-sink flows."""

    def __init__(self, d: DiGraph, demands: Dict[int, int]):
        self.d = d
        self.total = sum(demands.values())
        self.net = SourcedNetwork(d, dict(sorted(demands.items())))
        self.sinks = sorted(d.compute)

    @classmethod
    def transplant(cls, base: "_RootedProber", d: DiGraph,
                   demands: Dict[int, int]) -> "_RootedProber":
        """Rooted analogue of `_TheoremEightProber.transplant`: inherit the
        base run's network and per-sink warm flows, rewrite every capacity
        to `d`'s (and the source edges to the new demands).  Requires the
        same demand keys (same root set) as the base run."""
        if set(demands) != set(base.net.src_eid):
            raise ValueError("transplant requires identical demand roots")
        self = cls.__new__(cls)
        self.d = d
        self.total = sum(demands.values())
        self.net = base.net.clone(g=d)
        self.sinks = sorted(d.compute)
        net = self.net
        for e, eid in net.eid.items():
            net.set_cap_id(eid, d.cap.get(e, 0))
        for u, eid in net.src_eid.items():
            net.set_cap_id(eid, demands[u])
        return self

    def sync(self, edges: Sequence[Edge]) -> None:
        for e in edges:
            if e[0] != e[1]:
                self.net.set_cap(*e, self.d.cap.get(e, 0))

    def split_cap(self, u: int, w: int, t: int,
                  expect: Optional[int] = None) -> int:
        """Max M such that splitting (u,w),(w,t) by M keeps the rooted
        oracle.  Every cut's egress capacity is non-increasing in M under
        the split, so feasibility is monotone and a binary search on the
        oracle is exact (the closed form of Theorem 8 only covers the
        uniform all-roots case).  Each probe rewrites the three affected
        capacities and re-augments the warm per-sink flows.

        `expect` is a caller-guaranteed upper bound on the answer (replay
        under capacity domination): one feasibility check at it usually
        decides the whole search."""
        d, net = self.d, self.net
        c_uw = d.cap.get((u, w), 0)
        c_wt = d.cap.get((w, t), 0)
        bound = min(c_uw, c_wt)
        if expect is not None:
            bound = min(bound, expect)
        if bound <= 0:
            return 0
        c_ut = d.cap.get((u, t), 0)
        total, sinks = self.total, self.sinks

        def ok(m: int) -> bool:
            net.set_cap(u, w, c_uw - m)
            net.set_cap(w, t, c_wt - m)
            if u != t:
                net.set_cap(u, t, c_ut + m)
            return net.min_source_flow_at_least(sinks, total, warm=True)

        try:
            if ok(bound):
                return bound
            lo_ok, hi = 0, bound
            while hi - lo_ok > 1:
                mid = (lo_ok + hi) // 2
                if ok(mid):
                    lo_ok = mid
                else:
                    hi = mid
            return lo_ok
        finally:
            net.set_cap(u, w, c_uw)
            net.set_cap(w, t, c_wt)
            if u != t:
                net.set_cap(u, t, c_ut)

    def discard_cap(self, t: int, w: int,
                    expect: Optional[int] = None) -> int:
        return self.split_cap(t, w, t, expect=expect)


def max_split_capacity_rooted(d: DiGraph, demands: Dict[int, int],
                              u: int, w: int, t: int) -> int:
    """One-shot rooted maximum (fresh prober; Algorithm 1 keeps a shared
    warm prober across its whole run instead)."""
    return _RootedProber(d, demands).split_cap(u, w, t)


def remove_switches_rooted(d: DiGraph, demands: Dict[int, int],
                           pair_priority: Optional[PairPriority] = None,
                           verify: bool = False,
                           prober_factory=None,
                           prober_sink=None,
                           trace: bool = False) -> SplitResult:
    """Algorithm-1 loop with the rooted (broadcast/reduce) oracle: split off
    all switches while preserving min_v F(s, v) >= Σ demands for the
    demand-weighted super-source — enough to pack `demands[u]` spanning
    out-trees at each root u afterwards (Frank).  Eulerian graphs always
    admit a complete splitting-off, so the greedy loop terminates.

    `prober_factory` overrides the prober construction (repair passes a
    `_ReplayProber` over a transplant of a retained base-run prober);
    `prober_sink` receives the live prober after the run, for retention by
    a warm store; `trace=True` wraps the default prober in a
    `_TracingProber` so the sunk prober carries its decision log."""
    validate_eulerian(d)
    k = sum(demands.values())
    factory = prober_factory or (lambda dd: _RootedProber(dd, demands))
    if trace and prober_factory is None:
        factory = (lambda dd: _TracingProber(_RootedProber(dd, demands), dd))
    return _isolate_switches(
        d, k,
        prober_factory=factory,
        pair_priority=pair_priority, verify=verify,
        oracle=lambda dd: _oracle_holds_demands(dd, demands),
        prober_sink=prober_sink)


# ---------------------------------------------------------------------- #
# Decision traces: record one Algorithm-1 run, replay it against a delta
# ---------------------------------------------------------------------- #

@dataclasses.dataclass
class SplitTrace:
    """The decision log of one Algorithm-1 run: every prober call with its
    result, plus the residual capacities at each switch boundary.

    `events` holds ``(tag, u, w, t, m)`` rows — tag ``"s"`` for
    `split_cap(u, w, t)`, ``"d"`` for `discard_cap(u, w)` (recorded with
    ``t == u``; the loop never passes ``u == t`` to `split_cap`, so the tag
    disambiguates).  `segments` holds ``(switch, first_event_index,
    residual_caps)`` per isolated switch, in loop order.
    """
    events: List[Tuple[str, int, int, int, int]] = \
        dataclasses.field(default_factory=list)
    segments: List[Tuple[int, int, Dict[Edge, int]]] = \
        dataclasses.field(default_factory=list)


class _TracingProber:
    """Transparent prober wrapper that logs the run into a `SplitTrace`.

    `repro.core.plan.split` wraps every cold prober with this so the warm
    store retains, next to the prober itself, the exact decision sequence —
    the raw material `_ReplayProber` needs to skip work during a repair.
    The overhead is one tuple append per probe and one dict copy per
    switch, invisible next to the maxflows being logged.
    """

    def __init__(self, inner, d: DiGraph):
        self.inner = inner
        self.d = d
        self.trace = SplitTrace()

    def note_switch(self, w: int) -> None:
        self.trace.segments.append(
            (w, len(self.trace.events), dict(self.d.cap)))

    def sync(self, edges: Sequence[Edge]) -> None:
        self.inner.sync(edges)

    def split_cap(self, u: int, w: int, t: int) -> int:
        m = self.inner.split_cap(u, w, t)
        self.trace.events.append(("s", u, w, t, m))
        return m

    def discard_cap(self, u: int, w: int) -> int:
        m = self.inner.discard_cap(u, w)
        self.trace.events.append(("d", u, w, u, m))
        return m


class _ReplayProber:
    """Replay a base run's `SplitTrace` against a degraded residual,
    skipping every probe the trace proves is zero.

    Soundness rests on capacity monotonicity of the oracles: each
    Theorem-8 term is ``min_v F(src, snk; D̂) − |Vc|k`` with F a maxflow of
    the residual capacities, and the rooted oracle is a feasibility
    threshold on the same flows — both non-decreasing when capacities
    grow.  So while the degraded residual is pointwise *dominated* by the
    base residual at the aligned trace position (``cap'(e) <= cap(e)``
    everywhere), any candidate the base run probed to zero is a proven
    zero for the degraded run too and is answered without touching the
    oracle.  Positive base results only bound the degraded value from
    above, so picks are always probed for real (on the transplanted warm
    network, where they re-augment little).

    Alignment: at each switch boundary the wrapper checks domination
    against the recorded residual snapshot and enters sync; within a
    segment it advances the cursor past base zero-probes (they left the
    base residual untouched) until the current candidate matches.  A pick
    whose probed value differs from the recorded one, a base *pick* the
    degraded enumeration skipped, or cursor exhaustion all break the
    segment out of sync — every later candidate of that switch is probed
    for real, which is plain cold semantics and always correct.  The next
    boundary re-checks domination and may re-enter sync.

    The wrapper records its own `SplitTrace` while replaying, so a
    repaired artifact's retained prober can seed yet another repair.
    """

    def __init__(self, inner, d: DiGraph, base_trace: SplitTrace):
        self.inner = inner
        self.d = d
        self.base = base_trace
        self.trace = SplitTrace()
        self.skipped = 0            # probes answered from the trace
        self.probed = 0             # probes that hit the oracle
        self._seg = -1
        self._cur = 0               # cursor into base.events
        self._end = 0
        self._sync = False

    def note_switch(self, w: int) -> None:
        self.trace.segments.append(
            (w, len(self.trace.events), dict(self.d.cap)))
        segs = self.base.segments
        j = self._seg + 1
        if j < len(segs) and segs[j][0] == w:
            self._seg = j
            self._cur = segs[j][1]
            self._end = (segs[j + 1][1] if j + 1 < len(segs)
                         else len(self.base.events))
            snap = segs[j][2]
            self._sync = all(c <= snap.get(e, 0)
                             for e, c in self.d.cap.items())
        else:                       # structural mismatch: never sync again
            self._seg = len(segs)
            self._sync = False

    def sync(self, edges: Sequence[Edge]) -> None:
        self.inner.sync(edges)

    def _consume(self, tag: str, u: int, w: int, t: int) -> Optional[int]:
        """Advance the cursor to this candidate's base event and return its
        recorded value, or None (desynchronised)."""
        ev = self.base.events
        while self._cur < self._end:
            btag, bu, bw, bt, bm = ev[self._cur]
            if (btag, bu, bw, bt) == (tag, u, w, t):
                self._cur += 1
                return bm
            if bm != 0:
                # a base pick our enumeration skipped: residuals diverge
                return None
            self._cur += 1          # foreign zero-probe: base residual
        return None                 # unchanged, safe to pass over

    def _answer(self, tag: str, u: int, w: int, t: int,
                probe: Callable[[Optional[int]], int]) -> int:
        if self._sync:
            bm = self._consume(tag, u, w, t)
            if bm == 0:
                self.skipped += 1
                self.trace.events.append((tag, u, w, t, 0))
                return 0
            if bm is not None:
                # domination bounds the degraded answer by the base one, so
                # the prober may clamp its search at `expect` and stay exact
                m = probe(bm)
                self.probed += 1
                self.trace.events.append((tag, u, w, t, m))
                if m != bm:
                    self._sync = False
                return m
            self._sync = False
        m = probe(None)
        self.probed += 1
        self.trace.events.append((tag, u, w, t, m))
        return m

    def split_cap(self, u: int, w: int, t: int) -> int:
        return self._answer(
            "s", u, w, t,
            lambda e: self.inner.split_cap(u, w, t, expect=e))

    def discard_cap(self, u: int, w: int) -> int:
        return self._answer(
            "d", u, w, u,
            lambda e: self.inner.discard_cap(u, w, expect=e))


# ---------------------------------------------------------------------- #
# Algorithm 1
# ---------------------------------------------------------------------- #

def remove_switches(d: DiGraph, k: int,
                    pair_priority: Optional[PairPriority] = None,
                    verify: bool = False,
                    prober_factory=None,
                    prober_sink=None,
                    trace: bool = False) -> SplitResult:
    """Algorithm 1: split off all switch nodes of `d` (capacities already
    scaled to G({U b_e})), preserving the Theorem-5 tree-packing condition.

    pair_priority(u, w, t) orders ingress candidates per egress edge — the
    paper uses this hook (§2.2 example) to e.g. prefer cross-cluster pairs.
    `prober_factory` overrides the prober construction (repair passes a
    `_ReplayProber` over a transplant of a retained base-run prober);
    `prober_sink` receives the live prober after the run, for retention by
    a warm store; `trace=True` wraps the default prober in a
    `_TracingProber` so the sunk prober carries its decision log.
    """
    validate_eulerian(d)
    factory = prober_factory or (lambda dd: _TheoremEightProber(dd, k))
    if trace and prober_factory is None:
        factory = (lambda dd: _TracingProber(_TheoremEightProber(dd, k), dd))
    return _isolate_switches(
        d, k,
        prober_factory=factory,
        pair_priority=pair_priority, verify=verify,
        oracle=lambda dd: _oracle_holds(dd, k),
        prober_sink=prober_sink)


def _isolate_switches(d: DiGraph, k: int,
                      prober_factory,
                      pair_priority: Optional[PairPriority],
                      verify: bool, oracle, prober_sink=None) -> SplitResult:
    """Shared Algorithm-1 saturation loop, parameterised by the maximum-
    splittable-capacity prober (Theorem-8 closed form for allgather,
    warm binary search for the rooted variants).  One prober — and its
    incremental oracle network — lives for the whole run; applied splits
    are mirrored into it instead of triggering rebuilds."""
    original = d.copy()
    d = d.copy()
    prober = prober_factory(d)
    routing: Dict[Edge, Dict[int, int]] = {}

    def apply_split(u: int, w: int, t: int, m: int) -> None:
        for e in ((u, w), (w, t)):
            d.cap[e] -= m
            if d.cap[e] == 0:
                del d.cap[e]
        if u != t:
            d.cap[(u, t)] = d.cap.get((u, t), 0) + m
            routing.setdefault((u, t), {})
            routing[(u, t)][w] = routing[(u, t)].get(w, 0) + m
        prober.sync(((u, w), (w, t), (u, t)))

    boundary = getattr(prober, "note_switch", None)
    for w in sorted(d.switches):
        if boundary is not None:
            boundary(w)             # trace/replay probers log the residual
        # saturate every egress edge of w in turn
        guard = 0
        while True:
            egress = sorted(t for (a, t) in d.cap if a == w)
            if not egress:
                break
            guard += 1
            if guard > 4 * (d.num_nodes ** 2 + len(d.cap) + 4):
                raise EdgeSplitError(f"no progress isolating switch {w}")
            progress = False
            for t in egress:
                if d.cap.get((w, t), 0) == 0:
                    continue
                ins = [a for (a, b) in d.cap if b == w and a != t]
                if pair_priority is not None:
                    ins.sort(key=lambda u: pair_priority(u, w, t))
                else:
                    ins.sort()
                for u in ins:
                    if d.cap.get((w, t), 0) == 0:
                        break
                    m = prober.split_cap(u, w, t)
                    if m > 0:
                        apply_split(u, w, t, m)
                        progress = True
                # degenerate leftover: (t,w),(w,t) must be discarded
                if d.cap.get((w, t), 0) > 0 and d.cap.get((t, w), 0) > 0:
                    m = prober.discard_cap(t, w)
                    if m > 0:
                        apply_split(t, w, t, m)
                        progress = True
            if not progress:
                raise EdgeSplitError(
                    f"stuck isolating switch {w}: residual "
                    f"{{e: c for e, c in d.cap.items() if w in e}}")
        # w should now be isolated
        residual = [(e, c) for e, c in d.cap.items() if w in e]
        if residual:
            raise EdgeSplitError(f"switch {w} not isolated: {residual}")

    star = DiGraph(d.num_nodes, d.compute, d.cap, original.name + "*")
    if verify:
        validate_eulerian(star)
        if not oracle(star):
            raise EdgeSplitError("edge splitting broke the packing oracle")
    if prober_sink is not None:
        prober_sink(prober)
    return SplitResult(graph=star, routing=routing, original=original, k=k)


# ---------------------------------------------------------------------- #
# Path recovery: logical (u,t) capacity -> physical switch paths in G
# ---------------------------------------------------------------------- #

Path = Tuple[int, ...]


def expand_paths(res: SplitResult) -> Dict[Edge, List[Tuple[Path, int]]]:
    """Decompose every logical edge of D* into physical paths of G with
    integer capacities (a valid flow decomposition; conservation is exact)."""
    phys_pool: Dict[Edge, int] = dict(res.original.cap)
    via_pool: Dict[Edge, Dict[int, int]] = {
        e: dict(ws) for e, ws in res.routing.items()}

    def expand(a: int, b: int, amount: int) -> List[Tuple[Path, int]]:
        out: List[Tuple[Path, int]] = []
        take = min(amount, phys_pool.get((a, b), 0))
        if take:
            phys_pool[(a, b)] -= take
            out.append(((a, b), take))
            amount -= take
        for w in sorted(via_pool.get((a, b), {})):
            if amount == 0:
                break
            avail = via_pool[(a, b)][w]
            m = min(amount, avail)
            if m == 0:
                continue
            via_pool[(a, b)][w] -= m
            left = expand(a, w, m)
            right = expand(w, b, m)
            out.extend(_join(left, right))
            amount -= m
        if amount != 0:
            raise EdgeSplitError(
                f"path expansion under-supplied for ({a},{b}): short {amount}")
        return out

    result: Dict[Edge, List[Tuple[Path, int]]] = {}
    for (u, t), c in sorted(res.graph.cap.items()):
        result[(u, t)] = expand(u, t, c)
    return result


def _join(left: List[Tuple[Path, int]],
          right: List[Tuple[Path, int]]) -> List[Tuple[Path, int]]:
    """Splice a->..->w path pieces with w->..->b pieces, capacity-matched."""
    out: List[Tuple[Path, int]] = []
    li = ri = 0
    lpath, lcap = (left[0] if left else ((), 0))
    rpath, rcap = (right[0] if right else ((), 0))
    while li < len(left) and ri < len(right):
        m = min(lcap, rcap)
        out.append((lpath + rpath[1:], m))
        lcap -= m
        rcap -= m
        if lcap == 0:
            li += 1
            if li < len(left):
                lpath, lcap = left[li]
        if rcap == 0:
            ri += 1
            if ri < len(right):
                rpath, rcap = right[ri]
    return out


def trivial_split(d: DiGraph, k: int) -> SplitResult:
    """For already direct-connect topologies §2.2 is skippable."""
    if d.switches:
        raise ValueError("graph has switches; use remove_switches")
    return SplitResult(graph=d.copy(), routing={}, original=d.copy(), k=k)
