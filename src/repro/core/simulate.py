"""Round-accurate simulator + correctness verifier for pipeline schedules.

Two roles:

1. **Verifier** — replays the schedule chunk by chunk and proves semantic
   correctness: allgather delivers every root's every chunk to every node
   (store-and-forward discipline enforced); reduce-scatter accumulates each
   rank's contribution exactly once into the destination root's shard.

2. **Bandwidth simulator** — computes the exact runtime of the *pipelined*
   schedule on the **physical** topology G (chunks traverse the concrete
   switch paths assigned at compile time).  Round time = max over physical
   links of (bytes this round) / (link bandwidth); total = Σ rounds.  As the
   chunk count P grows this converges to the paper's optimum (M/N)·(1/x*) —
   the §1.3 minimality-or-saturation argument made executable.

Everything is exact rational arithmetic (fractions.Fraction): "equals the
lower bound" is checked with ==, not allclose.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from fractions import Fraction
from typing import Dict, List, Optional, Set, Tuple

from .graph import DiGraph, Edge
from .schedule import AllReduceSchedule, PipelineSchedule, Send


class ScheduleError(AssertionError):
    pass


@dataclasses.dataclass
class SimReport:
    kind: str
    num_rounds: int
    sim_time: Fraction          # runtime on physical links (M = data_size)
    lb_time: Fraction           # paper lower bound for this collective
    link_bytes: Dict[Edge, Fraction]  # physical per-link totals
    num_chunks: int

    @property
    def ratio(self) -> float:
        return float(self.sim_time / self.lb_time) if self.lb_time else 1.0

    def describe(self) -> str:
        return (f"{self.kind}: rounds={self.num_rounds} P={self.num_chunks} "
                f"T={float(self.sim_time):.6g} LB={float(self.lb_time):.6g} "
                f"ratio={self.ratio:.4f}")


# ---------------------------------------------------------------------- #
# physical link loads per round
# ---------------------------------------------------------------------- #

def _unit_paths(sched: PipelineSchedule
                ) -> Dict[Tuple[int, Edge], List[Tuple[int, ...]]]:
    """Flatten each (class, edge) path allocation to per-capacity-unit paths
    (len == class multiplicity)."""
    out: Dict[Tuple[int, Edge], List[Tuple[int, ...]]] = {}
    for key, alloc in sched.path_assignment.items():
        units: List[Tuple[int, ...]] = []
        for path, cap in alloc:
            units.extend([path] * cap)
        out[key] = units
    return out


def _round_times(sched: PipelineSchedule, data_size: Fraction,
                 reverse_paths: bool) -> Tuple[Fraction, Dict[Edge, Fraction]]:
    """Total pipelined runtime + physical per-link byte totals."""
    n = sched.num_nodes
    # rooted collectives move one buffer of M bytes; alltoall moves one
    # send buffer of M bytes per node (N blocks of M/N, slots_per_shard
    # already counts all N·k·P of them); the gathered/scattered family
    # moves N shards of M/N bytes each
    chunk = Fraction(data_size, sched.slots_per_shard) \
        if sched.kind in ("broadcast", "reduce", "alltoall") else \
        Fraction(data_size, n * sched.slots_per_shard)
    # reduce-scatter schedules carry paths in transpose-graph orientation;
    # after flipping the hops below they are in original-graph orientation,
    # so the bandwidth table is always sched.topo.cap as-is.
    unit_paths = _unit_paths(sched)
    bw = {e: Fraction(c) for e, c in sched.topo.cap.items()}
    total_time = Fraction(0)
    link_bytes: Dict[Edge, Fraction] = {}
    for rnd in sched.rounds:
        # group sends per (cls, logical edge) to index into capacity units
        per_key: Dict[Tuple[int, Edge], int] = {}
        load: Dict[Edge, int] = {}
        for s in sorted(rnd, key=lambda s: (s.cls, s.slot)):
            logical_e = (s.src, s.dst)
            key = (s.cls, logical_e if not reverse_paths
                   else (s.dst, s.src))
            idx = per_key.get(key, 0)
            per_key[key] = idx + 1
            path = unit_paths[key][idx]
            hops = list(zip(path[:-1], path[1:]))
            if reverse_paths:
                hops = [(b, a) for (a, b) in hops]
            for hop in hops:
                load[hop] = load.get(hop, 0) + 1
        if not load:
            continue
        rt = max(Fraction(cnt, 1) * chunk / bw[hop]
                 for hop, cnt in load.items())
        total_time += rt
        for hop, cnt in load.items():
            link_bytes[hop] = link_bytes.get(hop, Fraction(0)) + cnt * chunk
    return total_time, link_bytes


# ---------------------------------------------------------------------- #
# allgather
# ---------------------------------------------------------------------- #

def verify_allgather_delivery(sched: PipelineSchedule) -> None:
    """Replay: every node must end with every (root, slot) chunk; chunks may
    only be forwarded in a strictly later round than received."""
    nodes = sched.nodes
    slots = sched.slots_per_shard
    have: Dict[int, Set[Tuple[int, int]]] = {
        v: {(v, s) for s in range(slots)} for v in nodes}
    for rnd_i, rnd in enumerate(sched.rounds):
        incoming: List[Tuple[int, Tuple[int, int]]] = []
        for s in rnd:
            chunk = (s.root, s.slot)
            if chunk not in have[s.src]:
                raise ScheduleError(
                    f"round {rnd_i}: {s.src}->{s.dst} forwards {chunk} "
                    f"not yet held (store-and-forward violation)")
            incoming.append((s.dst, chunk))
        for dst, chunk in incoming:
            have[dst].add(chunk)
    want = {(r, s) for r in nodes for s in range(slots)}
    for v in nodes:
        if have[v] != want:
            missing = sorted(want - have[v])[:5]
            raise ScheduleError(f"node {v} missing chunks, e.g. {missing}")


def simulate_allgather(sched: PipelineSchedule,
                       data_size: Fraction = Fraction(1),
                       verify: bool = True) -> SimReport:
    """Exact pipelined allgather runtime on the physical topology, after
    (optionally) replaying every chunk through the delivery verifier; the
    report's lb_time is the eq (1) bound (M/N)·(1/x*)."""
    if verify:
        verify_allgather_delivery(sched)
    t, link_bytes = _round_times(sched, data_size, reverse_paths=False)
    lb = data_size * sched.lb_runtime_factor()
    return SimReport("allgather", len(sched.rounds), t, lb, link_bytes,
                     sched.num_chunks)


# ---------------------------------------------------------------------- #
# broadcast
# ---------------------------------------------------------------------- #

def verify_broadcast_delivery(sched: PipelineSchedule) -> None:
    """Replay: every node must end with all λ·P chunks of the root's buffer;
    a chunk may only be forwarded in a strictly later round than received."""
    root = sched.classes[0].root
    slots = sched.slots_per_shard
    have: Dict[int, Set[Tuple[int, int]]] = {
        v: set() for v in sched.nodes}
    have[root] = {(root, s) for s in range(slots)}
    for rnd_i, rnd in enumerate(sched.rounds):
        inc = []
        for s in rnd:
            if (s.root, s.slot) not in have[s.src]:
                raise ScheduleError(
                    f"round {rnd_i}: broadcast forwards unheld chunk")
            inc.append((s.dst, (s.root, s.slot)))
        for dst, ch in inc:
            have[dst].add(ch)
    for v in sched.nodes:
        if len(have[v]) != slots:
            raise ScheduleError(f"broadcast: node {v} incomplete")


def simulate_broadcast(sched: PipelineSchedule,
                       data_size: Fraction = Fraction(1),
                       verify: bool = True) -> SimReport:
    """Exact pipelined broadcast runtime; lb_time is the eq (5) per-root
    bound M/λ(root) (sched.k = λ)."""
    if verify:
        verify_broadcast_delivery(sched)
    t, link_bytes = _round_times(sched, data_size, reverse_paths=False)
    lb = data_size * Fraction(1, sched.k)  # eq (5): M / min-cut, k = λ
    return SimReport("broadcast", len(sched.rounds), t, lb, link_bytes,
                     sched.num_chunks)


# ---------------------------------------------------------------------- #
# reduce (edge-reversed broadcast with op fusion)
# ---------------------------------------------------------------------- #

def verify_reduce(sched: PipelineSchedule) -> None:
    """Replay with contribution counters: every node starts holding its own
    partial for each of the λ·P chunk slots; partials flow up the reversed
    trees (accumulating at every hop — op fusion); at the end the root must
    hold, for every slot, exactly one contribution from every rank."""
    root = sched.classes[0].root
    nodes = sched.nodes
    slots = sched.slots_per_shard
    state: Dict[int, Dict[int, Counter]] = {
        v: {s: Counter({v: 1}) for s in range(slots)} for v in nodes}
    for rnd_i, rnd in enumerate(sched.rounds):
        moves: List[Tuple[int, int, Counter]] = []
        for s in rnd:
            payload = state[s.src].get(s.slot)
            if payload is None:
                raise ScheduleError(
                    f"round {rnd_i}: {s.src} re-sends already-sent slot "
                    f"{s.slot} (fusion violation: a node forwards each "
                    f"accumulated partial exactly once)")
            moves.append((s.dst, s.slot, payload))
            del state[s.src][s.slot]          # the partial leaves the sender
        for dst, slot, payload in moves:
            acc = state[dst].get(slot)
            if acc is None:
                state[dst][slot] = Counter(payload)
            else:
                acc.update(payload)
    full = Counter({v: 1 for v in nodes})
    for s in range(slots):
        got = state[root].get(s)
        if got != full:
            raise ScheduleError(
                f"reduce root {root} slot {s}: contributions "
                f"{dict(got or {})} != one from every rank")


def simulate_reduce(sched: PipelineSchedule,
                    data_size: Fraction = Fraction(1),
                    verify: bool = True) -> SimReport:
    """Exact pipelined reduce runtime (contribution-counter replay when
    verify=True); lb_time is the eq (5) dual M / min cut into the root."""
    if verify:
        verify_reduce(sched)
    t, link_bytes = _round_times(sched, data_size, reverse_paths=True)
    lb = data_size * Fraction(1, sched.k)  # eq (5) dual: M / min cut into root
    return SimReport("reduce", len(sched.rounds), t, lb, link_bytes,
                     sched.num_chunks)


# ---------------------------------------------------------------------- #
# reduce-scatter
# ---------------------------------------------------------------------- #

def verify_reduce_scatter(sched: PipelineSchedule) -> None:
    """Replay with contribution counters: at the end, root r must hold, for
    each of its slots, exactly one contribution from every rank."""
    nodes = sched.nodes
    slots = sched.slots_per_shard
    # state[v][(root, slot)] = Counter{rank: times contributed}
    state: Dict[int, Dict[Tuple[int, int], Counter]] = {
        v: {(r, s): Counter({v: 1}) for r in nodes for s in range(slots)}
        for v in nodes}
    for rnd_i, rnd in enumerate(sched.rounds):
        moves: List[Tuple[int, Tuple[int, int], Counter]] = []
        for s in rnd:
            chunk = (s.root, s.slot)
            payload = state[s.src].get(chunk)
            if payload is None:
                raise ScheduleError(
                    f"round {rnd_i}: {s.src} re-sends already-sent {chunk}")
            moves.append((s.dst, chunk, payload))
            del state[s.src][chunk]          # partials leave the sender
        for dst, chunk, payload in moves:
            acc = state[dst].get(chunk)
            if acc is None:
                state[dst][chunk] = Counter(payload)
            else:
                acc.update(payload)
    full = Counter({v: 1 for v in nodes})
    for r in nodes:
        for s in range(slots):
            got = state[r].get((r, s))
            if got != full:
                raise ScheduleError(
                    f"root {r} slot {s}: contributions {dict(got or {})} "
                    f"!= one from every rank")


def simulate_reduce_scatter(sched: PipelineSchedule,
                            data_size: Fraction = Fraction(1),
                            verify: bool = True) -> SimReport:
    """Exact pipelined reduce-scatter runtime (physical paths traversed in
    reverse of the transpose-graph orientation they were assigned in);
    lb_time equals allgather's eq (1) bound by Appendix-B duality."""
    if verify:
        verify_reduce_scatter(sched)
    t, link_bytes = _round_times(sched, data_size, reverse_paths=True)
    lb = data_size * sched.lb_runtime_factor()
    return SimReport("reduce_scatter", len(sched.rounds), t, lb, link_bytes,
                     sched.num_chunks)


# ---------------------------------------------------------------------- #
# alltoall (per-source pruned scatter)
# ---------------------------------------------------------------------- #

def verify_alltoall_delivery(sched: PipelineSchedule) -> None:
    """Replay: chunk (root=r, slot=dest·kP+sub) must end at its destination,
    store-and-forward enforced; the diagonal (r → r) block must never be
    scheduled (its buffer rows are the staged input)."""
    nodes = sched.nodes
    stride = sched.k * sched.num_chunks          # subslots per dest block
    pos = {v: i for i, v in enumerate(nodes)}
    have: Dict[int, Set[Tuple[int, int]]] = {
        v: {(v, s) for s in range(sched.slots_per_shard)} for v in nodes}
    for rnd_i, rnd in enumerate(sched.rounds):
        incoming: List[Tuple[int, Tuple[int, int]]] = []
        for s in rnd:
            chunk = (s.root, s.slot)
            if chunk not in have[s.src]:
                raise ScheduleError(
                    f"round {rnd_i}: {s.src}->{s.dst} forwards {chunk} "
                    f"not yet held (store-and-forward violation)")
            if s.slot // stride == pos[s.root]:
                raise ScheduleError(
                    f"round {rnd_i}: diagonal block of root {s.root} "
                    f"scheduled ({s.src}->{s.dst} slot {s.slot}) — the "
                    f"self block never travels")
            incoming.append((s.dst, chunk))
        for dst, chunk in incoming:
            have[dst].add(chunk)
    for w in nodes:
        want = {(r, pos[w] * stride + t)
                for r in nodes if r != w for t in range(stride)}
        missing = want - have[w]
        if missing:
            raise ScheduleError(
                f"alltoall: node {w} missing chunks, e.g. "
                f"{sorted(missing)[:5]}")


def simulate_alltoall(sched: PipelineSchedule,
                      data_size: Fraction = Fraction(1),
                      verify: bool = True) -> SimReport:
    """Exact pipelined alltoall runtime on the physical topology;
    lb_time is the certified-cut bound `alltoall_lb` — for any compute
    cut S, the |S|·(N−|S|) cross blocks of M/N bytes must cross B+(S)."""
    if verify:
        verify_alltoall_delivery(sched)
    from .lower_bounds import alltoall_lb
    t, link_bytes = _round_times(sched, data_size, reverse_paths=False)
    lb = data_size * alltoall_lb(sched.topo)
    return SimReport("alltoall", len(sched.rounds), t, lb, link_bytes,
                     sched.num_chunks)


# ---------------------------------------------------------------------- #
# allreduce
# ---------------------------------------------------------------------- #

def simulate_allreduce(ar: AllReduceSchedule,
                       data_size: Fraction = Fraction(1),
                       verify: bool = True) -> SimReport:
    """Exact runtime of the composed RS+AG allreduce (both halves verified
    independently); lb_time is the RS+AG optimum 2·(M/N)·(1/x*), which is
    the true allreduce optimum under the Theorem-19 conditions."""
    rs = simulate_reduce_scatter(ar.rs, data_size, verify)
    ag = simulate_allgather(ar.ag, data_size, verify)
    link_bytes = dict(rs.link_bytes)
    for e, b in ag.link_bytes.items():
        link_bytes[e] = link_bytes.get(e, Fraction(0)) + b
    return SimReport("allreduce", rs.num_rounds + ag.num_rounds,
                     rs.sim_time + ag.sim_time,
                     data_size * ar.runtime_factor(),
                     link_bytes, ar.rs.num_chunks)


# ---------------------------------------------------------------------- #
# cut-traffic minimality (paper §1.3 requirement (b))
# ---------------------------------------------------------------------- #

def cut_traffic(report: SimReport, cut: Set[int]) -> Fraction:
    """Total bytes that crossed out of `cut` (physical links)."""
    return sum((b for (u, v), b in report.link_bytes.items()
                if u in cut and v not in cut), Fraction(0))
