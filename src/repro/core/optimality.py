"""§2.1 Optimality binary search.

Computes the exact rational value of the allgather lower bound

    1/x* = max_{S ⊂ V, S ⊉ Vc} |S ∩ Vc| / B+_G(S)          (paper eq. 1)

using the Theorem-1 maxflow oracle inside a binary search, then recovers the
exact fraction via Proposition 2 (denominator bound) + the continued-fraction
"simplest fraction in an interval" routine.  Proposition 3 then yields the
minimal tree multiplicity k and capacity multiplier U with U/k = 1/x*.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from fractions import Fraction
from typing import Optional, Tuple

from .graph import DiGraph, validate_eulerian
from .maxflow import SourcedNetwork


# ---------------------------------------------------------------------- #
# Theorem 1 oracle
# ---------------------------------------------------------------------- #

def _oracle_net(g: DiGraph) -> SourcedNetwork:
    """The Theorem-1 D_k shape (super-source tied to every compute node),
    built once per search and re-scaled per probe.  The sink sweep adapts
    across probes (the network remembers the last failing sink and tries
    it first), so the infeasible half of the binary search usually fails
    after a single maxflow.  Flows stay cold per probe: a probe rescales
    *every* capacity by a new numerator, so there is no small delta for
    the warm-start engine to re-augment (unlike the §2.2 searches)."""
    return SourcedNetwork(g, {u: 0 for u in sorted(g.compute)})


def _feasible_on(net: SourcedNetwork, runtime: Fraction) -> bool:
    if runtime <= 0:
        return False
    p, q = runtime.numerator, runtime.denominator
    net.rescale_graph_caps(p)
    net.set_source_caps(q)
    threshold = net.g.num_compute * q
    return net.min_source_flow_at_least(sorted(net.g.compute), threshold)


def oracle_feasible(g: DiGraph, runtime: Fraction) -> bool:
    """True iff `runtime` >= 1/x*, i.e. min_v F(s, v; G_x) >= |Vc| x with
    x = 1/runtime (Theorem 1).  Implemented with integer-scaled capacities:
    runtime = p/q  =>  scale topology caps by p, source edges get cap q,
    threshold |Vc|*q."""
    return _feasible_on(_oracle_net(g), runtime)


def check_reachable(g: DiGraph) -> None:
    """Allgather requires every compute node reachable from every other."""
    for root in sorted(g.compute):
        seen = {root}
        stack = [root]
        adj: dict[int, list[int]] = {}
        for (u, v) in g.cap:
            adj.setdefault(u, []).append(v)
        while stack:
            u = stack.pop()
            for v in adj.get(u, ()):  # capacities are positive by invariant
                if v not in seen:
                    seen.add(v)
                    stack.append(v)
        missing = g.compute - seen
        if missing:
            raise ValueError(
                f"{g.name}: compute node(s) {sorted(missing)} unreachable "
                f"from {root}; allgather impossible")


# ---------------------------------------------------------------------- #
# Simplest fraction in a closed interval (continued fractions)
# ---------------------------------------------------------------------- #

def simplest_between(lo: Fraction, hi: Fraction) -> Fraction:
    """The fraction with the smallest denominator in [lo, hi] (ties: smallest
    numerator).  Standard Stern–Brocot / continued-fraction descent."""
    if lo > hi:
        raise ValueError(f"empty interval [{lo}, {hi}]")
    if lo == hi:
        return lo
    if lo <= 0 <= hi:
        return Fraction(0)
    if hi < 0:
        return -simplest_between(-hi, -lo)
    # now 0 < lo < hi
    fl = lo.numerator // lo.denominator  # floor(lo)
    if Fraction(fl) >= lo:
        return Fraction(fl)
    if Fraction(fl + 1) <= hi:
        return Fraction(fl + 1)
    inner = simplest_between(1 / (hi - fl), 1 / (lo - fl))
    return fl + 1 / inner


# ---------------------------------------------------------------------- #
# The binary search itself
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class Optimality:
    """Result of the §2.1 search for topology G.

    inv_x_star : 1/x* — optimal bandwidth runtime in units of (M/N)/bandwidth
    U          : capacity multiplier (Prop 3); G({U b_e}) has integer caps
    k          : number of spanning trees per compute-node root (minimal)
    """
    inv_x_star: Fraction
    U: Fraction
    k: int

    @property
    def runtime_factor(self) -> Fraction:
        """T_B = (M/N) * runtime_factor (bandwidth units)."""
        return self.inv_x_star


def allgather_inv_xstar(g: DiGraph,
                        net: Optional[SourcedNetwork] = None) -> Fraction:
    """Binary search of §2.1; returns exact rational 1/x*.

    `net` lets callers pass in (and afterwards retain) the Theorem-1
    oracle network — `repro.core.repair` keeps it warm for later
    delta-recompiles of the same topology.  It must be bound to `g`."""
    check_reachable(g)
    n = g.num_compute
    if n == 1:
        return Fraction(0)
    dmin = g.min_compute_ingress()
    if dmin <= 0:
        raise ValueError(f"{g.name}: a compute node has zero ingress")
    lo = Fraction(n - 1, dmin)
    hi = Fraction(n - 1)
    if net is None:
        net = _oracle_net(g)      # one network serves every probe below
    assert net.g is g, "oracle network bound to a different graph"
    if _feasible_on(net, lo):
        return lo
    # invariant: lo infeasible (< 1/x*), hi feasible (>= 1/x*)
    gap = Fraction(1, dmin * dmin)
    while hi - lo > gap:
        mid = (lo + hi) / 2
        if _feasible_on(net, mid):
            hi = mid
        else:
            lo = mid
    # 1/x* is the unique fraction with denominator <= dmin in [lo, hi]
    # (Proposition 2); `simplest_between` finds it.
    cand = simplest_between(lo, hi)
    assert cand.denominator <= dmin, (cand, dmin)
    assert _feasible_on(net, cand), f"recovered {cand} not feasible"
    return cand


def choose_U_k(g: DiGraph, inv_x_star: Fraction) -> Tuple[Fraction, int]:
    """Proposition 3: minimal k with U/k = 1/x* and U*b_e integral."""
    if inv_x_star == 0:  # single compute node: no communication
        return Fraction(0), 1
    p, q = inv_x_star.numerator, inv_x_star.denominator
    gcd_b = g.bandwidth_gcd()
    gden = math.gcd(q, gcd_b)
    U = Fraction(p, gden)
    k = q // gden
    assert U / k == inv_x_star
    return U, k


def solve_optimality(g: DiGraph,
                     net: Optional[SourcedNetwork] = None) -> Optimality:
    """Full §2.1: exact 1/x*, then minimal (U, k)."""
    validate_eulerian(g)
    inv = allgather_inv_xstar(g, net=net)
    U, k = choose_U_k(g, inv)
    return Optimality(inv_x_star=inv, U=U, k=k)


# ---------------------------------------------------------------------- #
# Brute-force reference (exponential; used by tests on small graphs)
# ---------------------------------------------------------------------- #

def brute_force_inv_xstar(g: DiGraph) -> Fraction:
    """Enumerate every cut S ⊂ V with S ⊉ Vc — O(2^|V|), tests only."""
    best = Fraction(0)
    nodes = list(range(g.num_nodes))
    for r in range(1, g.num_nodes + 1):
        for s in itertools.combinations(nodes, r):
            ss = set(s)
            if g.compute <= ss:
                continue
            nc = len(ss & g.compute)
            if nc == 0:
                continue
            out = g.egress_set(ss)
            if out == 0:
                raise ValueError("disconnected cut; allgather impossible")
            best = max(best, Fraction(nc, out))
    return best
