"""Integer-capacity directed graph — the topology representation.

The paper models a network as a digraph ``G = (Vs ∪ Vc, E)`` where ``Vc`` are
compute nodes, ``Vs`` are switch nodes, and every directed edge carries an
integer capacity (think: number of unit-bandwidth multi-edges).  All of the
schedule compiler (optimality search, edge splitting, arborescence packing)
operates on this representation.

Conventions
-----------
* Nodes are integers ``0..num_nodes-1``.
* ``compute`` is the set of compute nodes; every other node is a switch.
* ``cap[(u, v)]`` is the integer capacity of directed edge ``(u, v)``.
  Absent key == no edge.  Self-loops are disallowed.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from fractions import Fraction
from typing import Dict, FrozenSet, Iterable, Iterator, List, Mapping, Tuple

Edge = Tuple[int, int]


@dataclasses.dataclass
class DiGraph:
    num_nodes: int
    compute: FrozenSet[int]
    cap: Dict[Edge, int]
    name: str = "G"

    # ------------------------------------------------------------------ #
    # construction / validation
    # ------------------------------------------------------------------ #
    def __post_init__(self) -> None:
        self.compute = frozenset(self.compute)
        self.cap = dict(self.cap)
        self.validate()

    def validate(self) -> None:
        if self.num_nodes <= 0:
            raise ValueError("graph must have at least one node")
        for u in self.compute:
            if not (0 <= u < self.num_nodes):
                raise ValueError(f"compute node {u} out of range")
        if not self.compute:
            raise ValueError("graph must have at least one compute node")
        for (u, v), c in self.cap.items():
            if u == v:
                raise ValueError(f"self-loop on node {u}")
            if not (0 <= u < self.num_nodes and 0 <= v < self.num_nodes):
                raise ValueError(f"edge ({u},{v}) out of range")
            if not isinstance(c, int) or c <= 0:
                raise ValueError(f"edge ({u},{v}) capacity must be positive int, got {c!r}")

    # ------------------------------------------------------------------ #
    # basic queries
    # ------------------------------------------------------------------ #
    @property
    def switches(self) -> FrozenSet[int]:
        return frozenset(range(self.num_nodes)) - self.compute

    @property
    def num_compute(self) -> int:
        return len(self.compute)

    def edges(self) -> Iterator[Tuple[Edge, int]]:
        return iter(self.cap.items())

    def out_edges(self, u: int) -> List[Tuple[int, int]]:
        """[(v, cap)] for every edge u -> v."""
        return [(v, c) for (a, v), c in self.cap.items() if a == u]

    def in_edges(self, u: int) -> List[Tuple[int, int]]:
        """[(v, cap)] for every edge v -> u."""
        return [(a, c) for (a, b), c in self.cap.items() if b == u]

    def egress(self, u: int) -> int:
        """Total egress capacity B+_G(u)."""
        return sum(c for (a, _), c in self.cap.items() if a == u)

    def ingress(self, u: int) -> int:
        """Total ingress capacity B-_G(u)."""
        return sum(c for (_, b), c in self.cap.items() if b == u)

    def egress_set(self, s: Iterable[int]) -> int:
        """Total capacity leaving the node set S, i.e. B+_G(S)."""
        ss = set(s)
        return sum(c for (u, v), c in self.cap.items() if u in ss and v not in ss)

    def ingress_set(self, s: Iterable[int]) -> int:
        ss = set(s)
        return sum(c for (u, v), c in self.cap.items() if u not in ss and v in ss)

    def is_eulerian(self) -> bool:
        """Every node has equal total ingress and egress capacity."""
        return all(self.egress(v) == self.ingress(v) for v in range(self.num_nodes))

    def min_compute_ingress(self) -> int:
        return min(self.ingress(v) for v in sorted(self.compute))

    def bandwidth_gcd(self) -> int:
        return math.gcd(*self.cap.values()) if self.cap else 1

    # ------------------------------------------------------------------ #
    # content addressing
    # ------------------------------------------------------------------ #
    def canonical_form(self) -> str:
        """Deterministic text encoding of the topology *structure*: node
        count, compute set, switch set and the sorted edge/capacity multiset.
        The display `name` is deliberately excluded so two differently-named
        builds of the same topology share one cache entry."""
        edges = ";".join(f"{u},{v},{c}" for (u, v), c in sorted(self.cap.items()))
        return (f"n={self.num_nodes}|c={','.join(map(str, sorted(self.compute)))}"
                f"|s={','.join(map(str, sorted(self.switches)))}|e={edges}")

    def fingerprint(self) -> str:
        """Content-addressed key for schedule caching (hex, 16 chars)."""
        return hashlib.sha256(self.canonical_form().encode()).hexdigest()[:16]

    # ------------------------------------------------------------------ #
    # transforms
    # ------------------------------------------------------------------ #
    def copy(self, name: str | None = None) -> "DiGraph":
        return DiGraph(self.num_nodes, self.compute, dict(self.cap),
                       name or self.name)

    def transpose(self) -> "DiGraph":
        """Reverse every edge (used for reduce-scatter = reversed allgather)."""
        return DiGraph(self.num_nodes, self.compute,
                       {(v, u): c for (u, v), c in self.cap.items()},
                       self.name + "^T")

    def scaled(self, factor: Fraction | int) -> "DiGraph":
        """Return G({factor * b_e}); every scaled capacity must be integral."""
        factor = Fraction(factor)
        new_cap: Dict[Edge, int] = {}
        for e, c in self.cap.items():
            scaled = factor * c
            if scaled.denominator != 1:
                raise ValueError(
                    f"capacity {c} * {factor} is not integral on edge {e}")
            if scaled > 0:
                new_cap[e] = int(scaled)
        return DiGraph(self.num_nodes, self.compute, new_cap,
                       f"{self.name}*{factor}")

    def floor_scaled(self, factor: Fraction | int) -> "DiGraph":
        """Return G({floor(factor * b_e)}) — used by fixed-k optimality (§2.4)."""
        factor = Fraction(factor)
        new_cap: Dict[Edge, int] = {}
        for e, c in self.cap.items():
            scaled = int(factor * c)  # floor for positive values
            if scaled > 0:
                new_cap[e] = scaled
        return DiGraph(self.num_nodes, self.compute, new_cap,
                       f"{self.name}*floor({factor})")

    def restricted_to(self, nodes: Iterable[int]) -> "DiGraph":
        """Induced subgraph on `nodes` (node ids are remapped to 0..len-1)."""
        order = sorted(set(nodes))
        remap = {v: i for i, v in enumerate(order)}
        cap = {(remap[u], remap[v]): c for (u, v), c in self.cap.items()
               if u in remap and v in remap}
        compute = frozenset(remap[v] for v in self.compute if v in remap)
        return DiGraph(len(order), compute, cap, self.name + "|sub")

    # ------------------------------------------------------------------ #
    # pretty printing
    # ------------------------------------------------------------------ #
    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"DiGraph({self.name!r}, n={self.num_nodes}, "
                f"compute={sorted(self.compute)}, edges={len(self.cap)})")

    def describe(self) -> str:
        lines = [f"{self.name}: {self.num_nodes} nodes "
                 f"({self.num_compute} compute, {len(self.switches)} switch), "
                 f"{len(self.cap)} edges"]
        for (u, v), c in sorted(self.cap.items()):
            lines.append(f"  {u} -> {v}  cap={c}")
        return "\n".join(lines)


def validate_eulerian(g: DiGraph) -> None:
    """Raise with a helpful message if g is not Eulerian (paper assumption b)."""
    bad = [(v, g.egress(v), g.ingress(v))
           for v in range(g.num_nodes) if g.egress(v) != g.ingress(v)]
    if bad:
        msg = ", ".join(f"node {v}: out={o} in={i}" for v, o, i in bad)
        raise ValueError(f"topology {g.name} is not Eulerian: {msg}")
