"""Pipeline schedule IR — from spanning trees to executable comm rounds.

A `PipelineSchedule` is the deployable artifact: a static list of rounds,
each a list of `Send(src, dst, root, slot)` operations at chunk granularity.
Chunking implements the paper's §1.3 resolution of the minimality-or-
saturation dilemma: each of the k trees per root streams P chunks, so the
runtime converges to the optimum as (P + depth − 1)/P → 1.

Builders (the full collective family the paper's abstract promises):
  compile_allgather      — §2.1-2.3 end-to-end (optimality, split, pack)
  compile_reduce_scatter — allgather on the transpose graph, reversed
                           (paper Appendix B / Zhao et al. [19] App. A)
  compile_allreduce      — RS + AG concatenation (Appendix B)
  compile_broadcast      — Appendix A: λ(r) = min_v F(r, v; G) edge-disjoint
                           out-trees from one root; switched topologies go
                           through the rooted edge-splitting variant
  compile_reduce         — broadcast on the transpose graph, reversed, with
                           the accumulation (op fusion) happening bottom-up
                           along each reversed tree
  compile_alltoall       — per-source pruned scatter over the same packed
                           spanning trees (Basu/Pal/Zhao et al. direct-
                           connect all-to-all): tree edge (a, b) of root r
                           forwards only the chunks whose destination lies
                           in subtree(b), so each (r, w) block travels the
                           unique r→w tree path and nothing else

All of them are thin wrappers over the staged pipeline in
`repro.core.plan` (solve → split → pack → rounds → lower), which records
per-stage wall time and size stats on the emitted artifact
(`PipelineSchedule.compile_stats`) and can amortize shared stages across
a whole collective family (`plan.compile_family`).

Physical path assignment: every tree-edge unit of capacity is bound to a
concrete switch path of the original graph G (via the edge-splitting
`routing` table), so the simulator can re-validate the bandwidth bound on
*physical* links, and a deployment can emit per-link send/recv programs.
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

from .arborescence import TreeClass, max_tree_depth
from .edge_split import SplitResult, expand_paths
from .graph import DiGraph, Edge
from .maxflow import build_network
from .optimality import Optimality


class Send(NamedTuple):
    """One chunk transfer on the logical graph D*.

    A NamedTuple rather than a (frozen) dataclass: schedules materialize
    millions of these and tuple construction is several times cheaper than
    a frozen dataclass's per-field object.__setattr__."""
    src: int
    dst: int
    root: int      # whose shard this chunk belongs to
    slot: int      # chunk slot within the root's shard: [0, k*P) for the
                   # allgather family, [0, N*k*P) for alltoall (the slot
                   # folds the destination in: dest_index*k*P + subslot)
    cls: int       # class index (for path assignment / debugging)


@dataclasses.dataclass
class PipelineSchedule:
    """The deployable artifact: a static list of chunk-granular rounds plus
    everything needed to re-verify it (optimality result, tree classes,
    edge-splitting routing, physical path assignment).  Serialized by
    `repro.cache.serialize`; lowered to ppermute programs by
    `repro.comms.compile_program`."""
    kind: str                      # allgather | reduce_scatter |
                                   # broadcast | reduce | alltoall
    topo: DiGraph                  # original G (possibly with switches)
    dstar: DiGraph                 # logical compute-only graph (caps U*b_e)
    opt: Optimality
    classes: List[TreeClass]
    split: SplitResult
    num_chunks: int                # P — pipeline chunks per tree
    rounds: List[List[Send]]
    class_slot_offset: List[int]   # per class: first slot within root shard
    # physical path assignment: (cls, edge) -> [(path, units), ...]
    path_assignment: Dict[Tuple[int, Edge], List[Tuple[Tuple[int, ...], int]]]
    # exact pipelined runtime (data_size=1) claimed by the compiler; filled
    # in by the simulator / cache layer, carried by serialized artifacts so
    # a loaded schedule can be re-verified against its claim.
    claimed_runtime: Optional[Fraction] = None
    # per-stage compiler instrumentation (repro.core.plan.CompileStats).
    # Not part of the canonical artifact payload — the cache stores it in a
    # stats sidecar, the sweep copies it into BENCH rows.
    compile_stats: Optional[Any] = None

    @property
    def nodes(self) -> List[int]:
        return sorted(self.dstar.compute)

    @property
    def num_nodes(self) -> int:
        return len(self.dstar.compute)

    @property
    def k(self) -> int:
        return self.opt.k

    @property
    def root(self) -> Optional[int]:
        """The single root of a broadcast/reduce schedule (None otherwise)."""
        if self.kind in ("broadcast", "reduce"):
            return self.classes[0].root
        return None

    @property
    def slots_per_shard(self) -> int:
        """Chunk slots per source shard.  The allgather family splits each
        node's shard into k·P slots; alltoall carries N distinct destination
        blocks per source, each split into k·P subslots."""
        if self.kind == "alltoall":
            return self.num_nodes * self.opt.k * self.num_chunks
        return self.opt.k * self.num_chunks

    @property
    def depth(self) -> int:
        return max_tree_depth(self.classes)

    def total_sends(self) -> int:
        return sum(len(r) for r in self.rounds)

    def lb_runtime_factor(self) -> Fraction:
        """Optimal T_B per unit data M per unit bandwidth: (1/N)·(1/x*)."""
        return self.opt.inv_x_star / self.num_nodes

    def describe(self) -> str:
        return (f"{self.kind} on {self.topo.name}: N={self.num_nodes} "
                f"k={self.k} P={self.num_chunks} depth={self.depth} "
                f"rounds={len(self.rounds)} sends={self.total_sends()} "
                f"1/x*={self.opt.inv_x_star}")


# ---------------------------------------------------------------------- #
# Allgather round construction (store-and-forward over the tree pipeline)
# ---------------------------------------------------------------------- #

def _build_allgather_rounds(
        classes: Sequence[TreeClass], num_chunks: int
) -> Tuple[List[List[Send]], List[int]]:
    """Chunk-granular rounds: per round, each tree edge of class c forwards
    up to m_c in-order chunks (m_c = class multiplicity = its capacity
    share on every one of its edges)."""
    # slot offsets: classes of the same root occupy disjoint slot ranges
    offset: List[int] = []
    per_root: Dict[int, int] = {}
    for c in classes:
        offset.append(per_root.get(c.root, 0))
        per_root[c.root] = per_root.get(c.root, 0) + c.mult * num_chunks

    total = [c.mult * num_chunks for c in classes]          # chunks per class
    received = [{c.root: total[i]} for i, c in enumerate(classes)]
    sent: List[Dict[Edge, int]] = [dict() for _ in classes]

    rounds: List[List[Send]] = []
    done = False
    while not done:
        this_round: List[Send] = []
        # deliveries land after the round: reads below see pre-round state,
        # writes are deferred (cheaper than copying every class's dict)
        pending: List[Tuple[int, int, int]] = []
        for ci, c in enumerate(classes):
            got_ci, sent_ci = received[ci], sent[ci]
            mult, tot, off, root = c.mult, total[ci], offset[ci], c.root
            for e in c.edges:
                a, b = e
                s = sent_ci.get(e, 0)
                n = min(mult, got_ci.get(a, 0) - s, tot - s)
                if n <= 0:
                    continue
                this_round.extend(
                    Send(a, b, root, off + t, ci) for t in range(s, s + n))
                sent_ci[e] = s + n
                pending.append((ci, b, n))
        for ci, b, n in pending:
            received[ci][b] = received[ci].get(b, 0) + n
        if not this_round:
            # all deliveries complete?
            done = all(
                received[ci].get(v, 0) == total[ci]
                for ci, c in enumerate(classes) for v in c.verts)
            if not done:
                raise RuntimeError("pipeline stalled before completion")
        else:
            rounds.append(this_round)
            done = all(
                received[ci].get(v, 0) == total[ci]
                for ci, c in enumerate(classes) for v in c.verts)
    return rounds, offset


# ---------------------------------------------------------------------- #
# All-to-all round construction (pruned scatter over the same packed trees)
# ---------------------------------------------------------------------- #

def _build_alltoall_rounds(
        classes: Sequence[TreeClass], num_chunks: int, k: int
) -> Tuple[List[List[Send]], List[int]]:
    """Per-source scatter rounds over the all-roots §2.3 packing.

    Each spanning tree of root r carries r's traffic to *every*
    destination, but pruned: edge (a, b) forwards only the chunks whose
    destination lies in subtree(b), so the (r, w) block travels exactly
    the unique r→w tree path.  Slots fold the destination in —
    ``slot = dest_index·k·P + class_offset + t`` — which keeps `Send`,
    the serializer and the executor's ``root·S + slot`` addressing
    unchanged (S grows to N·k·P).  The diagonal (r, r) block is never
    sent; its buffer rows are simply the staged input.

    Per round each tree edge forwards up to ``mult`` chunks (its capacity
    share) in a fixed deepest-destination-first order, store-and-forward:
    a chunk crosses an edge strictly after the round that delivered it to
    the edge's tail.  Returns ``(rounds, class_slot_offset)`` with the
    same offset semantics as the allgather builder.
    """
    offset: List[int] = []
    per_root: Dict[int, int] = {}
    for c in classes:
        offset.append(per_root.get(c.root, 0))
        per_root[c.root] = per_root.get(c.root, 0) + c.mult * num_chunks
    stride = k * num_chunks                    # subslots per dest block
    nodes = sorted({v for c in classes for v in c.verts})
    pos = {v: i for i, v in enumerate(nodes)}

    # static per-class structure: per-edge destination queues (deepest
    # destination first — keeps downstream edges fed early) and the child
    # hop toward every destination below a vertex.  Queue order is a
    # single global (depth, id) key per class, so every edge consumes its
    # queue as an order-preserving subsequence of its parent's — arrivals
    # at the tail are always a prefix of the queue.
    queues: List[Dict[Edge, List[int]]] = []
    routes: List[Dict[Tuple[int, int], Edge]] = []
    for c in classes:
        children: Dict[int, List[int]] = {}
        for (a, b) in c.edges:
            children.setdefault(a, []).append(b)
        depth = {c.root: 0}
        order = [c.root]
        for v in order:
            for w in children.get(v, ()):
                depth[w] = depth[v] + 1
                order.append(w)
        sub: Dict[int, List[int]] = {}
        for v in reversed(order):              # leaves first
            s = [v]
            for w in children.get(v, ()):
                s.extend(sub[w])
            sub[v] = s
        q: Dict[Edge, List[int]] = {}
        rt: Dict[Tuple[int, int], Edge] = {}
        for (a, b) in c.edges:
            q[(a, b)] = sorted(sub[b], key=lambda w: (-depth[w], w))
            for w in sub[b]:
                rt[(a, w)] = (a, b)
        queues.append(q)
        routes.append(rt)

    mp = [c.mult * num_chunks for c in classes]   # chunks per (class, dest)
    sent = [dict.fromkeys(queues[ci], 0) for ci in range(len(classes))]
    avail: List[Dict[Edge, int]] = []
    for ci, c in enumerate(classes):
        avail.append({e: len(dests) * mp[ci] if e[0] == c.root else 0
                      for e, dests in queues[ci].items()})
    active = [list(c.edges) for c in classes]
    remaining = sum(len(dests) * mp[ci]
                    for ci in range(len(classes))
                    for dests in queues[ci].values())

    rounds: List[List[Send]] = []
    while remaining:
        this_round: List[Send] = []
        # deliveries land after the round: reads below see pre-round state
        pending: List[Tuple[Dict[Edge, int], Edge]] = []
        for ci, c in enumerate(classes):
            edges = active[ci]
            if not edges:
                continue
            q_ci, s_ci, a_ci, rt = queues[ci], sent[ci], avail[ci], routes[ci]
            mult, m, off, root = c.mult, mp[ci], offset[ci], c.root
            still: List[Edge] = []
            for e in edges:
                dests = q_ci[e]
                s = s_ci[e]
                n = min(mult, a_ci[e] - s)
                if n > 0:
                    a, b = e
                    for j in range(s, s + n):
                        w = dests[j // m]
                        this_round.append(
                            Send(a, b, root, pos[w] * stride + off + j % m,
                                 ci))
                        if w != b:
                            pending.append((a_ci, rt[(b, w)]))
                    s_ci[e] = s = s + n
                    remaining -= n
                if s < len(dests) * m:
                    still.append(e)
            active[ci] = still
        for a_ci, e in pending:
            a_ci[e] += 1
        if not this_round:
            raise RuntimeError("alltoall pipeline stalled before completion")
        rounds.append(this_round)
    return rounds, offset


# ---------------------------------------------------------------------- #
# Physical path assignment
# ---------------------------------------------------------------------- #

def _assign_paths(split: SplitResult, classes: Sequence[TreeClass]
                  ) -> Dict[Tuple[int, Edge], List[Tuple[Tuple[int, ...], int]]]:
    """Bind each class's per-edge capacity share to concrete physical paths
    (a flow decomposition of the edge-splitting routing table)."""
    pool = expand_paths(split)          # (u,t) -> [(path, cap)] totals = cap
    remaining: Dict[Edge, List[List]] = {
        e: [[list(p), c] for (p, c) in plist] for e, plist in pool.items()}
    assignment: Dict[Tuple[int, Edge], List[Tuple[Tuple[int, ...], int]]] = {}
    for ci, c in enumerate(classes):
        for e in c.edges:
            need = c.mult
            alloc: List[Tuple[Tuple[int, ...], int]] = []
            for slot in remaining.get(e, ()):  # [path, cap] mutable
                if need == 0:
                    break
                take = min(need, slot[1])
                if take > 0:
                    alloc.append((tuple(slot[0]), take))
                    slot[1] -= take
                    need -= take
            if need != 0:
                raise RuntimeError(
                    f"path pool exhausted for class {ci} edge {e} (short {need})")
            assignment[(ci, e)] = alloc
    return assignment


# ---------------------------------------------------------------------- #
# Public compilers (thin wrappers over the staged pipeline in plan.py)
# ---------------------------------------------------------------------- #

def compile_allgather(topo: DiGraph, num_chunks: int = 8,
                      fixed_k: Optional[int] = None,
                      pair_priority=None, verify: bool = False
                      ) -> PipelineSchedule:
    """End-to-end §2: bandwidth-optimal allgather pipeline schedule
    (staged: solve → split → pack → rounds)."""
    from . import plan as plan_mod
    return plan_mod.compile_plan(plan_mod.plan_for(
        "allgather", topo, num_chunks=num_chunks, fixed_k=fixed_k,
        pair_priority=pair_priority, verify=verify))


def compile_reduce_scatter(topo: DiGraph, num_chunks: int = 8,
                           fixed_k: Optional[int] = None,
                           pair_priority=None, verify: bool = False
                           ) -> PipelineSchedule:
    """Reduce-scatter = allgather compiled on G^T with all sends reversed
    (src/dst swapped, round order flipped).  In the reversed schedule every
    node forwards a chunk to its tree-parent only after all tree-children
    delivered theirs — the store-and-forward order of the forward schedule
    guarantees it."""
    from . import plan as plan_mod
    return plan_mod.compile_plan(plan_mod.plan_for(
        "reduce_scatter", topo, num_chunks=num_chunks, fixed_k=fixed_k,
        pair_priority=pair_priority, verify=verify))


@dataclasses.dataclass
class AllReduceSchedule:
    """RS + AG concatenation (paper Appendix B)."""
    rs: PipelineSchedule
    ag: PipelineSchedule

    @property
    def topo(self) -> DiGraph:
        return self.rs.topo

    @property
    def num_nodes(self) -> int:
        return self.rs.num_nodes

    def runtime_factor(self) -> Fraction:
        """2 · (M/N) · 1/x* per unit M — optimal under Theorem 19 conditions."""
        return self.rs.lb_runtime_factor() + self.ag.lb_runtime_factor()

    @property
    def claimed_runtime(self) -> Optional[Fraction]:
        if self.rs.claimed_runtime is None or self.ag.claimed_runtime is None:
            return None
        return self.rs.claimed_runtime + self.ag.claimed_runtime

    @property
    def compile_stats(self):
        """{'rs': CompileStats, 'ag': CompileStats} of the two halves
        (entries may be None for deserialized artifacts)."""
        return {"rs": self.rs.compile_stats, "ag": self.ag.compile_stats}

    def describe(self) -> str:
        return f"allreduce = [{self.rs.describe()}] + [{self.ag.describe()}]"


def compile_allreduce(topo: DiGraph, num_chunks: int = 8,
                      fixed_k: Optional[int] = None,
                      pair_priority=None, verify: bool = False
                      ) -> AllReduceSchedule:
    """Appendix B: pipelined allreduce as reduce-scatter composed with
    allgather — one `AllReduceSchedule` carrying both halves, serialized
    and cached as a single `repro.allreduce` artifact.  Optimal whenever
    Theorem 19's conditions hold (see `theorem19_rs_ag_optimal`).

    Compiled through `plan.compile_family`, so the §2.1 solve runs once
    and is shared between the two halves (exact by Eulerian transpose
    symmetry) instead of being recomputed per orientation."""
    from . import plan as plan_mod
    return plan_mod.compile_family(
        topo, kinds=("allreduce",), num_chunks=num_chunks, fixed_k=fixed_k,
        pair_priority=pair_priority, verify=verify)["allreduce"]


def broadcast_lambda(topo: DiGraph, root: int) -> int:
    """λ(root) = min_v F(root, v; G): the exact broadcast bandwidth of the
    root (paper eq. 5 specialised to one source) — an integer for integer
    capacities, so no Proposition-3 scaling is needed."""
    if root not in topo.compute:
        raise ValueError(f"broadcast root {root} is not a compute node")
    lam = None
    net = build_network(topo)          # one network, reset between sinks
    for v in sorted(topo.compute):
        if v == root:
            continue
        net.reset_flow()
        f = net.maxflow(root, v)
        lam = f if lam is None else min(lam, f)
    if not lam:
        raise ValueError("root cannot reach some compute node")
    return lam


def compile_broadcast(topo: DiGraph, root: int, num_chunks: int = 8,
                      pair_priority=None, verify: bool = False
                      ) -> PipelineSchedule:
    """Appendix A: pack λ(root) = min_v F(root, v; G) edge-disjoint out-trees
    from a single root; each tree streams 1/λ of the data as `num_chunks`
    pipelined chunks.  Switched topologies first go through the rooted
    edge-splitting variant, which preserves F(root, v) >= λ for every
    compute node v (Frank's rooted-packing condition) instead of the
    all-roots Theorem-5 oracle used by allgather."""
    from . import plan as plan_mod
    return plan_mod.compile_plan(plan_mod.plan_for(
        "broadcast", topo, num_chunks=num_chunks, root=root,
        pair_priority=pair_priority, verify=verify))


def compile_alltoall(topo: DiGraph, num_chunks: int = 8,
                     fixed_k: Optional[int] = None,
                     pair_priority=None, verify: bool = False
                     ) -> PipelineSchedule:
    """All-to-all as per-source pruned scatter (Basu/Pal/Zhao et al.,
    direct-connect all-to-all): reuse the §2.1 solve and the all-roots
    §2.2/§2.3 packing verbatim — the solve, split and pack products are
    identical to allgather's — and replace only the round construction:
    each source's k trees scatter N−1 distinct destination blocks along
    their unique tree paths instead of broadcasting one shard.  Shares
    packed products with allgather under `plan.compile_family`."""
    from . import plan as plan_mod
    return plan_mod.compile_plan(plan_mod.plan_for(
        "alltoall", topo, num_chunks=num_chunks, fixed_k=fixed_k,
        pair_priority=pair_priority, verify=verify))


def compile_reduce(topo: DiGraph, root: int, num_chunks: int = 8,
                   pair_priority=None, verify: bool = False
                   ) -> PipelineSchedule:
    """Reduce = broadcast compiled on G^T with all sends reversed (src/dst
    swapped, round order flipped) — the same duality that derives
    reduce-scatter from allgather.  In the reversed schedule every node
    forwards each chunk slot to its tree-parent only after all tree-children
    delivered theirs, so the reduction op is fused bottom-up along the tree:
    a node sends one accumulated partial per slot, never raw operands."""
    from . import plan as plan_mod
    return plan_mod.compile_plan(plan_mod.plan_for(
        "reduce", topo, num_chunks=num_chunks, root=root,
        pair_priority=pair_priority, verify=verify))
