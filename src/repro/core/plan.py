"""Staged schedule-compiler pipeline over the `CollectivePlan` IR.

The `compile_*` entry points in `repro.core.schedule` used to be monoliths
that re-derived shared intermediate state per collective.  They are now
thin wrappers over an explicit five-stage pipeline:

    stage 1  solve   §2.1 optimality / Appendix-A broadcast λ / §2.4 fixed-k
    stage 2  split   §2.2 switch removal (all-roots or rooted oracle)
    stage 3  pack    §2.3 arborescence / rooted-tree packing
    stage 4  rounds  §1.3 pipelined round construction + path assignment
    stage 5  lower   ppermute program lowering (repro.comms.compile_program)

Each of stages 1-4 is a pure function Plan → Plan (the input plan is never
mutated; products accumulate in a new plan), with wall time and size stats
recorded per stage in `CompileStats`.  The stats ride on the emitted
`PipelineSchedule`, into the schedule cache's stats sidecar, the sweep's
`BENCH_schedules.json` rows and the launch drivers' logs.

Dual kinds (`reduce_scatter`, `reduce`) compile forward on the transpose
graph and are emitted with every send reversed and the round order flipped
— exactly the Appendix-B duality the monoliths implemented.

`compile_family` amortizes shared stages across kinds.  The §2.1 solve is
computed once per topology and shared across the two orientations: for an
Eulerian graph every cut S has B+(S) = B-(S) (sum the per-node balance
over S), so eq. (1)'s `1/x*` — and with it Proposition 3's (U, k) — is
transpose-invariant.  Allreduce therefore solves once instead of twice,
and reuses the packed products of its allgather / reduce-scatter siblings
when those kinds are requested together.
"""
from __future__ import annotations

import dataclasses
import time
from fractions import Fraction
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from .arborescence import (TreeClass, max_tree_depth, pack_arborescences,
                           pack_rooted_trees, verify_rooted_packing)
from .edge_split import (PairPriority, SplitResult, remove_switches,
                         remove_switches_rooted, trivial_split)
from .fixed_k import solve_fixed_k
from .graph import DiGraph, Edge, validate_eulerian
from .maxflow import COUNTERS
from .optimality import Optimality, solve_optimality
from .schedule import (AllReduceSchedule, PipelineSchedule, Send,
                       _assign_paths, _build_allgather_rounds,
                       _build_alltoall_rounds, broadcast_lambda)

#: kinds a single `CollectivePlan` can carry (allreduce is a composite of
#: two plans — see `compile_family`).
PLAN_KINDS = ("allgather", "reduce_scatter", "broadcast", "reduce",
              "alltoall")
FAMILY_KINDS = PLAN_KINDS + ("allreduce",)
STAGES = ("solve", "split", "pack", "rounds", "lower")

_DUAL = frozenset(("reduce_scatter", "reduce"))     # compile forward on G^T
_ROOTED = frozenset(("broadcast", "reduce"))        # single-root λ family
#: same-orientation siblings whose solve/split/pack products are identical
#: (stages 1-3 never look at the kind beyond rooted-ness/orientation, so an
#: alltoall packing IS the allgather packing — only the rounds differ)
_FORWARD_SHARE = {"allgather": "alltoall", "alltoall": "allgather"}
#: transpose-dual donors for opt sharing (see `adopt_solution`)
_OPT_DONORS = {"allgather": ("reduce_scatter",),
               "alltoall": ("reduce_scatter",),
               "reduce_scatter": ("allgather", "alltoall")}


class PlanError(ValueError):
    pass


# ---------------------------------------------------------------------- #
# per-stage instrumentation
# ---------------------------------------------------------------------- #

@dataclasses.dataclass
class StageStat:
    """One pipeline stage's wall time plus small size/result stats.

    Stages that drive the maxflow oracle engine (solve/split/pack) also
    record ``probes`` (maxflow invocations, including warm-start drains)
    and ``augments`` (augmenting paths pushed) in `meta` — the counters
    perf work watches to see oracle reuse paying off."""
    stage: str
    wall_time_s: float
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"stage": self.stage, "wall_time_s": self.wall_time_s,
                "meta": dict(self.meta)}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "StageStat":
        return cls(stage=d["stage"], wall_time_s=d["wall_time_s"],
                   meta=dict(d.get("meta", {})))


@dataclasses.dataclass
class CompileStats:
    """Ordered per-stage record of one collective's compilation."""
    kind: str
    stages: List[StageStat] = dataclasses.field(default_factory=list)

    def with_stage(self, stage: str, wall_time_s: float,
                   **meta: Any) -> "CompileStats":
        """A new CompileStats with `stage` recorded (replacing any earlier
        record of the same stage, so re-lowering stays idempotent)."""
        kept = [s for s in self.stages if s.stage != stage]
        return CompileStats(self.kind,
                            kept + [StageStat(stage, wall_time_s, dict(meta))])

    def copy(self) -> "CompileStats":
        return CompileStats(self.kind, [
            StageStat(s.stage, s.wall_time_s, dict(s.meta))
            for s in self.stages])

    @property
    def total_time_s(self) -> float:
        return sum(s.wall_time_s for s in self.stages)

    def stage_seconds(self) -> Dict[str, float]:
        """{stage: wall seconds} in pipeline order."""
        return {s.stage: s.wall_time_s for s in self.stages}

    def describe(self) -> str:
        parts = " ".join(f"{s.stage}={s.wall_time_s * 1e3:.2f}ms"
                         for s in self.stages)
        return f"{self.kind}: {parts} total={self.total_time_s * 1e3:.2f}ms"

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind,
                "stages": [s.to_dict() for s in self.stages]}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CompileStats":
        return cls(kind=d["kind"],
                   stages=[StageStat.from_dict(s) for s in d["stages"]])


# ---------------------------------------------------------------------- #
# the IR
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class CollectivePlan:
    """Immutable state threaded through the staged compiler.

    `work` is the forward-orientation graph the stages operate on: the
    topology itself for forward kinds, its transpose for the dual kinds
    (whose schedules are emitted send-reversed).  Stage products start as
    None and are filled in by `solve` → `split` → `pack` → `rounds`;
    `emit` assembles the final `PipelineSchedule`.
    """
    kind: str
    topo: DiGraph                        # original user-facing topology
    work: DiGraph                        # forward-orientation graph
    num_chunks: int
    root: Optional[int] = None           # rooted kinds only
    fixed_k: Optional[int] = None        # §2.4 (non-rooted kinds only)
    pair_priority: Optional[PairPriority] = None
    verify: bool = False
    # stage products
    opt: Optional[Optimality] = None
    scaled: Optional[DiGraph] = None     # graph the splitter consumes
    split: Optional[SplitResult] = None
    classes: Optional[List[TreeClass]] = None
    rounds: Optional[List[List[Send]]] = None
    class_slot_offset: Optional[List[int]] = None
    path_assignment: Optional[
        Dict[Tuple[int, Edge], List[Tuple[Tuple[int, ...], int]]]] = None
    stats: CompileStats = dataclasses.field(
        default_factory=lambda: CompileStats(kind="?"))

    @property
    def is_dual(self) -> bool:
        return self.kind in _DUAL

    @property
    def is_rooted(self) -> bool:
        return self.kind in _ROOTED

    def describe(self) -> str:
        done = [s for s, p in (("solve", self.opt), ("split", self.split),
                               ("pack", self.classes), ("rounds", self.rounds))
                if p is not None]
        return (f"CollectivePlan[{self.kind}] on {self.topo.name} "
                f"P={self.num_chunks} stages_done={done}")


def plan_for(kind: str, topo: DiGraph, num_chunks: int = 8,
             root: Optional[int] = None, fixed_k: Optional[int] = None,
             pair_priority: Optional[PairPriority] = None,
             verify: bool = False) -> CollectivePlan:
    """A fresh, un-run plan for one collective on `topo`."""
    if kind not in PLAN_KINDS:
        raise PlanError(f"unknown plan kind {kind!r} (one of {PLAN_KINDS})")
    if kind in _ROOTED:
        if root is None:
            raise PlanError(f"{kind} plans need an explicit root")
        if fixed_k is not None:
            raise PlanError(f"{kind} has no fixed-k variant (k = λ(root))")
    work = topo.transpose() if kind in _DUAL else topo
    return CollectivePlan(kind=kind, topo=topo, work=work,
                          num_chunks=num_chunks, root=root, fixed_k=fixed_k,
                          pair_priority=pair_priority, verify=verify,
                          stats=CompileStats(kind=kind))


def _require(plan: CollectivePlan, stage: str, need: str,
             have_not: str) -> None:
    if getattr(plan, have_not) is not None:
        raise PlanError(f"stage {stage!r} already ran for this plan")
    if need and getattr(plan, need) is None:
        raise PlanError(f"stage {stage!r} needs stage product {need!r} — "
                        f"run the earlier stages first ({plan.describe()})")


# ---------------------------------------------------------------------- #
# stages 1-4 (pure Plan -> Plan)
# ---------------------------------------------------------------------- #

def solve(plan: CollectivePlan) -> CollectivePlan:
    """Stage 1: the exact bandwidth-optimality result.

    Non-rooted kinds run the §2.1 binary search (or the §2.4 fixed-k
    search) on the forward graph and scale it to integer capacities;
    rooted kinds compute λ(root) = min_v F(root, v) (Appendix A eq. 5)."""
    _require(plan, "solve", "", "opt")
    t0 = time.perf_counter()
    c0 = COUNTERS.snapshot()
    w = plan.work
    meta: Dict[str, Any] = {"nodes": w.num_nodes, "edges": len(w.cap)}
    if plan.is_rooted:
        lam = broadcast_lambda(w, plan.root)
        opt = Optimality(inv_x_star=Fraction(len(w.compute), lam),
                         U=Fraction(1), k=lam)
        scaled = w
    elif plan.fixed_k is None:
        from .optimality import _oracle_net
        from .repair import WARM
        net = _oracle_net(w)
        opt = solve_optimality(w, net=net)
        WARM.offer_solve(w, net)    # retained for later delta-recompiles
        scaled = w.scaled(opt.U)
    else:
        res = solve_fixed_k(w, plan.fixed_k)
        opt = Optimality(inv_x_star=res.runtime_factor, U=res.U_star,
                         k=plan.fixed_k)
        scaled = w.floor_scaled(res.U_star)
        meta["fixed_k"] = plan.fixed_k
    wall = time.perf_counter() - t0
    return dataclasses.replace(
        plan, opt=opt, scaled=scaled,
        stats=plan.stats.with_stage("solve", wall, k=opt.k, U=str(opt.U),
                                    inv_x_star=str(opt.inv_x_star), **meta,
                                    **COUNTERS.delta(c0)))


def adopt_solution(plan: CollectivePlan, opt: Optimality) -> CollectivePlan:
    """Stage 1 by sharing: install an `Optimality` already solved for the
    *other orientation* of the same topology.

    Exact for Eulerian graphs: B+(S) = B-(S) for every cut S, so eq. (1)
    and Proposition 3's (U, k) are transpose-invariant.  Only valid for
    the non-rooted kinds with the automatic k (λ and the §2.4 floor are
    not transpose-symmetric in general)."""
    _require(plan, "solve", "", "opt")
    if plan.is_rooted or plan.fixed_k is not None:
        raise PlanError("solution sharing only applies to the automatic-k "
                        "allgather family")
    t0 = time.perf_counter()
    validate_eulerian(plan.work)    # the symmetry argument needs this
    scaled = plan.work.scaled(opt.U)
    wall = time.perf_counter() - t0
    return dataclasses.replace(
        plan, opt=opt, scaled=scaled,
        stats=plan.stats.with_stage("solve", wall, k=opt.k, U=str(opt.U),
                                    inv_x_star=str(opt.inv_x_star),
                                    shared="transpose"))


def split(plan: CollectivePlan, prober_factory=None) -> CollectivePlan:
    """Stage 2: §2.2 switch removal on the solved, scaled graph — the
    rooted oracle for broadcast/reduce, Theorem 8 for the rest; a trivial
    split when the topology is already direct-connect.

    `prober_factory` (graph -> prober) substitutes the Theorem-8 / rooted
    oracle — `repro.core.repair` passes transplanted warm probers through
    it.  Either way the finished prober is retained in the warm store for
    later delta-recompiles of the same scaled graph."""
    _require(plan, "split", "opt", "split")
    from .repair import WARM
    t0 = time.perf_counter()
    c0 = COUNTERS.snapshot()
    g = plan.scaled
    switched = g.switches and any(w in e for e in g.cap for w in g.switches)
    if plan.is_rooted:
        if switched:
            sink = (lambda p: WARM.offer_split(
                g, "rooted", (plan.root, plan.opt.k), p))
            res = remove_switches_rooted(g, {plan.root: plan.opt.k},
                                         pair_priority=plan.pair_priority,
                                         verify=plan.verify,
                                         prober_factory=prober_factory,
                                         prober_sink=sink,
                                         trace=prober_factory is None)
        else:
            res = trivial_split(g, plan.opt.k)
    elif switched:
        sink = lambda p: WARM.offer_split(g, "tree", plan.opt.k, p)
        res = remove_switches(g, plan.opt.k,
                              pair_priority=plan.pair_priority,
                              verify=plan.verify,
                              prober_factory=prober_factory,
                              prober_sink=sink,
                              trace=prober_factory is None)
    else:
        res = trivial_split(g, plan.opt.k)
    wall = time.perf_counter() - t0
    return dataclasses.replace(
        plan, split=res,
        stats=plan.stats.with_stage(
            "split", wall, switches=len(g.switches),
            logical_edges=len(res.graph.cap),
            routed_edges=len(res.routing), **COUNTERS.delta(c0)))


def pack(plan: CollectivePlan) -> CollectivePlan:
    """Stage 3: §2.3 spanning-tree packing on the compute-only graph —
    k trees per root (allgather family) or λ trees at the single root."""
    _require(plan, "pack", "split", "classes")
    t0 = time.perf_counter()
    c0 = COUNTERS.snapshot()
    if plan.is_rooted:
        demands = {plan.root: plan.opt.k}
        classes = pack_rooted_trees(plan.split.graph, demands)
        if plan.verify:
            verify_rooted_packing(plan.split.graph, demands, classes)
    else:
        classes = pack_arborescences(plan.split.graph, plan.opt.k)
    wall = time.perf_counter() - t0
    return dataclasses.replace(
        plan, classes=classes,
        stats=plan.stats.with_stage("pack", wall, classes=len(classes),
                                    depth=max_tree_depth(classes),
                                    **COUNTERS.delta(c0)))


def rounds(plan: CollectivePlan) -> CollectivePlan:
    """Stage 4: §1.3 chunk-granular store-and-forward rounds plus the
    physical path assignment binding tree edges to switch paths of G."""
    _require(plan, "rounds", "classes", "rounds")
    t0 = time.perf_counter()
    if plan.kind == "alltoall":
        rnds, offsets = _build_alltoall_rounds(plan.classes, plan.num_chunks,
                                               plan.opt.k)
    else:
        rnds, offsets = _build_allgather_rounds(plan.classes, plan.num_chunks)
    paths = _assign_paths(plan.split, plan.classes)
    wall = time.perf_counter() - t0
    return dataclasses.replace(
        plan, rounds=rnds, class_slot_offset=offsets, path_assignment=paths,
        stats=plan.stats.with_stage("rounds", wall, rounds=len(rnds),
                                    sends=sum(len(r) for r in rnds)))


def emit(plan: CollectivePlan) -> PipelineSchedule:
    """Assemble the deployable artifact from a fully-run plan.  Dual kinds
    get every send reversed and the round order flipped (Appendix B); the
    plan's stats ride along as an independent copy (artifacts emitted from
    shared plan products must not share mutable stats)."""
    if plan.rounds is None:
        raise PlanError(f"emit needs all four stages run ({plan.describe()})")
    if plan.is_dual:
        out_rounds = [
            [Send(src=s.dst, dst=s.src, root=s.root, slot=s.slot, cls=s.cls)
             for s in rnd]
            for rnd in reversed(plan.rounds)]
        dstar = plan.split.graph.transpose()
    else:
        out_rounds = plan.rounds
        dstar = plan.split.graph
    return PipelineSchedule(
        kind=plan.kind, topo=plan.topo, dstar=dstar, opt=plan.opt,
        classes=list(plan.classes), split=plan.split,
        num_chunks=plan.num_chunks, rounds=out_rounds,
        class_slot_offset=list(plan.class_slot_offset),
        path_assignment=plan.path_assignment,
        compile_stats=plan.stats.copy())


def compile_plan(plan: CollectivePlan) -> PipelineSchedule:
    """Run stages 1-4 and emit the artifact."""
    return emit(rounds(pack(split(solve(plan)))))


def lower(sched: PipelineSchedule):
    """Stage 5: lower the schedule to a static `lax.ppermute` program
    (`repro.comms.compile_program`), recording the lowering wall time into
    the artifact's `compile_stats`."""
    from repro.comms.executor import compile_program
    return compile_program(sched)


# ---------------------------------------------------------------------- #
# family compilation: amortize stages across collectives
# ---------------------------------------------------------------------- #

FamilyArtifact = Union[PipelineSchedule, AllReduceSchedule]


def _split_pack_worker(plan: CollectivePlan) -> CollectivePlan:
    """Process-pool body for `compile_family(jobs=...)`: finish one plan
    kind's chunk-count-independent stages.  Ships a solved (or fresh,
    for rooted kinds) plan to a worker process and returns the packed
    plan — stage stats (wall times + oracle counters) ride back inside
    it, so BENCH instrumentation survives the process hop; only the
    in-process warm-oracle offers are lost (documented trade-off)."""
    if plan.opt is None:
        plan = solve(plan)
    return pack(split(plan))


def compile_family(topo: DiGraph, kinds: Sequence[str] = FAMILY_KINDS,
                   num_chunks: int = 8, root: Optional[int] = None,
                   fixed_k: Optional[int] = None,
                   pair_priority: Optional[PairPriority] = None,
                   verify: bool = False,
                   timings: Optional[Dict[str, float]] = None,
                   packed_out: Optional[Dict[str, CollectivePlan]] = None,
                   jobs: int = 1) -> Dict[str, FamilyArtifact]:
    """Compile several collectives for one topology, sharing stages.

    * The §2.1 solve runs once and is shared across both orientations
      (exact — see `adopt_solution`), so allreduce never solves twice.
    * split/pack/rounds products are computed once per orientation and
      reused: `allreduce` is assembled from the same packed products as
      the `allgather` / `reduce_scatter` rows when requested together,
      and `alltoall` re-tags allgather's packed products outright (stages
      1-3 are kind-independent; only the rounds construction differs).
    * Rooted kinds (`broadcast`, `reduce`) need `root`; `fixed_k` applies
      to the allgather family only (rooted kinds always use k = λ(root)).
    * A `timings` dict (if given) receives each kind's *marginal* wall
      seconds — shared stage work is charged to the kind that triggered
      it, so the values sum to the family's total compile wall time (this
      is what the sweep records as per-row ``compile_time_s``).
    * A `packed_out` dict (if given) receives the packed (pre-rounds)
      plans by plan kind.  Stages 1-3 are chunk-count-independent, so a
      caller that discovers it needs a larger P (the sweep's P >= depth
      rule) can re-run only `rounds` + `emit` on a
      ``dataclasses.replace(plan, num_chunks=...)`` copy instead of
      recompiling the family.
    * ``jobs > 1`` runs the per-orientation split+pack stages in worker
      *processes* (each packed orientation/kind is independent once the
      solve is shared).  Artifacts stay byte-identical to the sequential
      path — only wall times in the stats sidecar differ, the family's
      parallel stage wall is charged to the first requested kind, and the
      in-process warm-oracle store sees no offers from worker plans.

    Returns {kind: artifact}, semantically identical (and byte-identical
    once serialized) to calling the per-kind `compile_*` entry points.
    """
    kinds = list(kinds)
    unknown = [k for k in kinds if k not in FAMILY_KINDS]
    if unknown:
        raise PlanError(f"unknown collective kinds {unknown} "
                        f"(choose from {FAMILY_KINDS})")
    packed: Dict[str, CollectivePlan] = {}
    full: Dict[str, CollectivePlan] = {}

    pre_wall = 0.0
    if jobs > 1:
        # expand to plan kinds in sequential trigger order (allreduce is
        # RS then AG — the same order the emit loop below uses)
        plan_kinds: List[str] = []
        for kind in kinds:
            # alltoall shares allgather's packed products outright, so the
            # workers pack allgather once and packed_plan() re-tags it
            for pk in (("reduce_scatter", "allgather")
                       if kind == "allreduce"
                       else ("allgather",) if kind == "alltoall"
                       else (kind,)):
                if pk not in plan_kinds:
                    plan_kinds.append(pk)
        if len(plan_kinds) > 1:
            t0 = time.perf_counter()
            todo: List[CollectivePlan] = []
            shared_opt: Optional[Optimality] = None
            for pk in plan_kinds:
                p = plan_for(pk, topo, num_chunks=num_chunks,
                             root=root if pk in _ROOTED else None,
                             fixed_k=fixed_k if pk not in _ROOTED else None,
                             pair_priority=pair_priority, verify=verify)
                if pk not in _ROOTED and fixed_k is None:
                    # exactly the sequential sharing: the first non-rooted
                    # kind solves, its transpose dual adopts that solution
                    if shared_opt is None:
                        p = solve(p)
                        shared_opt = p.opt
                    else:
                        p = adopt_solution(p, shared_opt)
                # rooted / fixed-k plans solve in their worker
                todo.append(p)
            import concurrent.futures
            with concurrent.futures.ProcessPoolExecutor(
                    max_workers=min(jobs, len(todo))) as ex:
                done = list(ex.map(_split_pack_worker, todo))
            packed.update({p.kind: p for p in done})
            pre_wall = time.perf_counter() - t0

    def packed_plan(kind: str) -> CollectivePlan:
        if kind in packed:
            return packed[kind]
        sib = _FORWARD_SHARE.get(kind)
        if sib is not None and sib in packed:
            # same orientation, same (fixed-)k: stages 1-3 are identical,
            # so re-tag the sibling's packed products instead of recomputing
            src = packed[sib]
            p = dataclasses.replace(
                src, kind=kind,
                stats=dataclasses.replace(src.stats.copy(), kind=kind))
            packed[kind] = p
            return p
        p = plan_for(kind, topo, num_chunks=num_chunks,
                     root=root if kind in _ROOTED else None,
                     fixed_k=fixed_k if kind not in _ROOTED else None,
                     pair_priority=pair_priority, verify=verify)
        donor = next((d for d in _OPT_DONORS.get(kind, ())
                      if d in packed), None) if fixed_k is None else None
        if donor is not None:
            p = adopt_solution(p, packed[donor].opt)
        else:
            p = solve(p)
        p = pack(split(p))
        packed[kind] = p
        return p

    def full_plan(kind: str) -> CollectivePlan:
        if kind not in full:
            full[kind] = rounds(packed_plan(kind))
        return full[kind]

    out: Dict[str, FamilyArtifact] = {}
    for kind in kinds:
        t0 = time.perf_counter()
        if kind == "allreduce":
            # RS first, AG adopts its solve — same order as the monolith
            rs = emit(full_plan("reduce_scatter"))
            ag = emit(full_plan("allgather"))
            out[kind] = AllReduceSchedule(rs=rs, ag=ag)
        else:
            out[kind] = emit(full_plan(kind))
        if timings is not None:
            timings[kind] = time.perf_counter() - t0
    if timings is not None and kinds:
        timings[kinds[0]] += pre_wall   # parallel stage wall (jobs > 1)
    if packed_out is not None:
        packed_out.update(packed)
    return out
