# The paper's primary contribution: a strongly polynomial-time compiler from
# arbitrary switched network topologies to bandwidth-optimal pipelined
# collective schedules (allgather / reduce-scatter / allreduce / broadcast /
# alltoall).
from .graph import DiGraph, Edge, validate_eulerian  # noqa: F401
from .maxflow import FlowNetwork, build_network, build_Dk  # noqa: F401
from .optimality import (Optimality, allgather_inv_xstar,  # noqa: F401
                         brute_force_inv_xstar, choose_U_k, oracle_feasible,
                         simplest_between, solve_optimality)
from .edge_split import (EdgeSplitError, SplitResult,  # noqa: F401
                         expand_paths, max_discard_capacity,
                         max_split_capacity, max_split_capacity_rooted,
                         remove_switches, remove_switches_rooted,
                         trivial_split)
from .arborescence import (PackingError, TreeClass,  # noqa: F401
                           max_tree_depth, pack_arborescences,
                           pack_rooted_trees, verify_packing,
                           verify_rooted_packing)
from .fixed_k import FixedKResult, fixed_k_feasible, solve_fixed_k  # noqa: F401
from .lower_bounds import (allgather_lb, allreduce_lb, alltoall_lb,  # noqa: F401
                           broadcast_lb,
                           broadcast_root_lb, brute_force_bottleneck_cut,
                           min_compute_separating_cut,
                           re_bc_allreduce_runtime, reduce_lb, reduce_root_lb,
                           rs_ag_allreduce_runtime, single_node_cut,
                           theorem19_rs_ag_optimal)
from .schedule import (AllReduceSchedule, PipelineSchedule, Send,  # noqa: F401
                       broadcast_lambda, compile_allgather, compile_allreduce,
                       compile_alltoall, compile_broadcast, compile_reduce,
                       compile_reduce_scatter)
from .plan import (CollectivePlan, CompileStats, PlanError,  # noqa: F401
                   StageStat, compile_family, compile_plan, plan_for)
from .simulate import (ScheduleError, SimReport, cut_traffic,  # noqa: F401
                       simulate_allgather, simulate_allreduce,
                       simulate_alltoall, simulate_broadcast, simulate_reduce,
                       simulate_reduce_scatter, verify_allgather_delivery,
                       verify_alltoall_delivery,
                       verify_broadcast_delivery, verify_reduce,
                       verify_reduce_scatter)
