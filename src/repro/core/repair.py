"""Online schedule repair: delta-recompile a degraded topology from the
warm oracle state the base compile left behind.

A link failing (or degrading) mid-run turns the fabric G into G' with
strictly smaller capacities.  Cold-compiling G' repeats the three oracle-
heavy stages — the §2.1 optimality search, the §2.2 edge splitting and the
§2.3 packing — from empty flow networks, even though G' differs from G on a
single edge.  Repair instead *transplants* the base run's retained networks
and re-derives each stage from capacity deltas:

solve   The degraded optimum is found by an exact Dinkelbach-style
        iteration started at the base ``1/x*``: capacity decreases only
        raise cut ratios (for every cut S, ``B+_{G'}(S) <= B+_G(S)``), so
        the base value is an achieved-ratio lower bound of the degraded
        value.  If the Theorem-1 oracle accepts it, it *is* the degraded
        ``1/x*``; otherwise the failing probe's min cut T yields the
        strictly larger achieved ratio ``|T∩Vc| / B+_{G'}(T)`` (from the
        cut arithmetic of eq. 1:  ``q·(n−|T∩Vc|) + p·B+_{G'}(T) < n·q``
        implies the ratio exceeds p/q), and the iteration repeats from it.
        Ratios strictly increase through achieved values, so the loop is
        finite and the result is exactly ``allgather_inv_xstar(G')`` — a
        handful of oracle probes instead of a whole binary search.  The
        probes themselves run on a clone of the base solve network rebound
        to G' (`SourcedNetwork.clone(g=...)`), skipping the rebuild.

split   Two warm layers.  (a) The base run's Theorem-8 prober (network,
        keyed term-flow snapshots, binding-sink history) is transplanted:
        every capacity is rewritten to the degraded scaled value through
        the target-tracking setters, so each term's first warm probe
        drains/augments exactly the inter-run delta instead of recomputing
        the |Vc|·k-unit base flow.  (b) The base compile records a
        `SplitTrace` — every prober call, its result, and a per-switch
        residual snapshot — and repair *replays* it through a
        `_ReplayProber`: while the degraded residual is pointwise dominated
        by the base residual at the aligned trace position, capacity
        monotonicity of maxflow makes every base zero-probe a proven zero
        for the degraded run (``m' <= m = 0``), so it is answered without
        touching the oracle; positive base results bound the degraded
        answer from above (the ``expect`` fast path: one feasibility check
        at the recorded value decides a binary search, and Theorem-8's
        running minimum starts at it).  Any mismatch — a pick value that
        differs, a base pick our enumeration skipped, trace exhaustion —
        desynchronises the replay, which then probes everything until the
        next switch boundary re-establishes domination against the
        recorded snapshot.  Every returned value is a genuine oracle probe
        or a monotonicity-proven zero, so the split trajectory, and with
        it the emitted bytes, match a cold compile of G' exactly.  The
        transplant/replay is only engaged when the degraded optimum keeps
        the base ``(U, k)`` (rooted: λ); a changed optimum rescales every
        capacity, the trace cannot align, and the split runs cold — which
        is always correct; the gate is purely about speed.

pack    §2.3 gadget networks are built per (class, tail) against the
        *residual* capacities at growth time, which diverge from the base
        run's after the first differing pick — there is no stable base
        state to transplant, so pack always runs fresh.  It is still
        warm within the run: `_MuGadget` keeps per-head flow snapshots
        across picks (see `repro.core.arborescence`).

rounds/lower are cheap, deterministic reconstructions and always rerun.

The repaired artifact is re-verified on the degraded graph (the simulator
replays every chunk) and is byte-identical to a cold compile of the
transformed topology — `tests/test_repair.py` pins this across the zoo.
"""
from __future__ import annotations

import dataclasses
import time
from fractions import Fraction
from typing import Any, Dict, Optional, Tuple, Union

from .edge_split import _ReplayProber, _RootedProber, _TheoremEightProber
from .graph import DiGraph, validate_eulerian
from .maxflow import COUNTERS, SourcedNetwork
from .optimality import (Optimality, _feasible_on, _oracle_net,
                         check_reachable, choose_U_k)
from .schedule import AllReduceSchedule, PipelineSchedule

__all__ = ["RepairError", "RepairReport", "WarmStore", "WARM",
           "repair_inv_xstar", "repair_artifact", "repair_schedule"]


class RepairError(RuntimeError):
    """Repair could not produce a verified schedule for the degraded graph."""


# ---------------------------------------------------------------------- #
# warm-state retention
# ---------------------------------------------------------------------- #

class WarmStore:
    """LRU retention of the oracle state a compile leaves behind, keyed by
    graph fingerprint, so a later repair can transplant it.

    * solve networks: ``work.fingerprint() -> SourcedNetwork`` (the §2.1
      D_k-shaped oracle, reusable for any transform of that work graph);
    * split probers: ``(scaled.fingerprint(), mode, param) -> prober``
      (mode "tree" with param k, or "rooted" with param (root, k)).

    Deposits happen inside `repro.core.plan.solve` / `split`; lookups only
    in this module.  Entries are bounded (`max_entries` per category,
    insertion-ordered eviction) — losing one only costs warmth, never
    correctness, since every repair path falls back to cold oracles.
    """

    def __init__(self, max_entries: int = 8):
        self.max_entries = max_entries
        self._solve: Dict[str, SourcedNetwork] = {}
        self._split: Dict[Tuple[str, str, Any], Any] = {}

    @staticmethod
    def _put(store: Dict, key, value, cap: int) -> None:
        store.pop(key, None)
        store[key] = value
        while len(store) > cap:
            store.pop(next(iter(store)))

    def offer_solve(self, work: DiGraph, net: SourcedNetwork) -> None:
        self._put(self._solve, work.fingerprint(), net, self.max_entries)

    def solve_net(self, fingerprint: str) -> Optional[SourcedNetwork]:
        return self._solve.get(fingerprint)

    def offer_split(self, scaled: DiGraph, mode: str, param,
                    prober) -> None:
        self._put(self._split, (scaled.fingerprint(), mode, param), prober,
                  self.max_entries)

    def split_prober(self, fingerprint: str, mode: str, param):
        return self._split.get((fingerprint, mode, param))

    def clear(self) -> None:
        self._solve.clear()
        self._split.clear()


#: process-wide store the staged compiler deposits into
WARM = WarmStore()


# ---------------------------------------------------------------------- #
# stage 1 repair: exact Dinkelbach iteration from the base optimum
# ---------------------------------------------------------------------- #

def repair_inv_xstar(degraded: DiGraph, base_inv: Fraction,
                     net: Optional[SourcedNetwork] = None,
                     max_rounds: int = 10_000) -> Tuple[Fraction, int]:
    """Exact degraded ``1/x*`` from the base value, by achieved-cut-ratio
    iteration (see module docstring for the argument).  Returns
    ``(inv_x_star, oracle_rounds)``; the value equals
    ``allgather_inv_xstar(degraded)`` exactly.

    `net` may be a Theorem-1 oracle network already bound to `degraded`
    (e.g. a transplanted clone of the base solve network); omitted, a
    fresh one is built.
    """
    check_reachable(degraded)
    n = degraded.num_compute
    if n == 1:
        return Fraction(0), 0
    dmin = degraded.min_compute_ingress()
    if dmin <= 0:
        raise RepairError(
            f"{degraded.name}: a compute node lost all ingress capacity")
    if net is None:
        net = _oracle_net(degraded)
    elif net.g is not degraded:
        raise RepairError("repair oracle network bound to the wrong graph")
    # both candidates are achieved cut ratios of the degraded graph (the
    # base 1/x* via capacity monotonicity), hence lower bounds of 1/x*'
    r = max(base_inv, Fraction(n - 1, dmin))
    for rounds in range(1, max_rounds + 1):
        if _feasible_on(net, r):
            # r is a lower bound *and* feasible (an upper bound): r = 1/x*'
            assert r.denominator <= dmin, (r, dmin)
            return r, rounds
        # the failing probe's min cut is a strictly-tighter achieved ratio
        v = net.last_failing
        assert v is not None
        side = set(net.net.min_cut_side(net.s))
        T = side - {net.s}
        nc = len(T & degraded.compute)
        egress = degraded.egress_set(T)
        if nc <= 0 or egress <= 0:  # pragma: no cover — invariant violation
            raise RepairError(
                f"degenerate failing cut while repairing {degraded.name}: "
                f"|T∩Vc|={nc}, B+(T)={egress} (failing sink {v})")
        r2 = Fraction(nc, egress)
        if r2 <= r:  # pragma: no cover — invariant violation
            raise RepairError(
                f"cut-ratio iteration stalled at {r} (next {r2}) "
                f"repairing {degraded.name}")
        r = r2
    raise RepairError(  # pragma: no cover — max_rounds is far beyond need
        f"no convergence after {max_rounds} rounds repairing {degraded.name}")


def _repair_optimality(work: DiGraph, base_opt: Optimality,
                       net: Optional[SourcedNetwork]
                       ) -> Tuple[Optimality, int]:
    """Degraded-work `Optimality`, exactly equal to `solve_optimality(work)`."""
    validate_eulerian(work)
    inv, rounds = repair_inv_xstar(work, base_opt.inv_x_star, net=net)
    U, k = choose_U_k(work, inv)
    return Optimality(inv_x_star=inv, U=U, k=k), rounds


# ---------------------------------------------------------------------- #
# full-pipeline repair
# ---------------------------------------------------------------------- #

@dataclasses.dataclass
class RepairReport:
    """What one repair did, and how warm it ran."""
    kind: str
    transform: str
    base_topology: str
    degraded_topology: str
    repair_time_s: float
    warm_solve: bool            # base solve network transplanted
    warm_split: bool            # base split prober transplanted
    solve_rounds: int           # Dinkelbach oracle rounds (0 = rooted path)
    verified: bool              # simulator replayed every chunk
    claimed_runtime: str        # exact Fraction as text
    cached: bool = False        # replayed from a .repair cache sidecar

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RepairReport":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def _replay_or_raw(transplanted, dd, entry):
    """Wrap the transplanted prober in a `_ReplayProber` over the base
    run's decision trace when the warm-store entry carries one (it always
    does for probers sunk by `plan.split`); a bare transplant otherwise."""
    trace = getattr(entry, "trace", None)
    if trace is None:
        return transplanted
    return _ReplayProber(transplanted, dd, trace)


def _transform_of(transform) -> "TransformSpec":
    from repro.topo.spec import TransformSpec
    if isinstance(transform, TransformSpec):
        return transform
    if isinstance(transform, str):
        return TransformSpec.parse_text(transform)
    raise TypeError(f"cannot interpret {type(transform).__name__!r} as a "
                    f"transform (takes TransformSpec | '@name(...)' string)")


def repair_schedule(artifact: PipelineSchedule, transform,
                    verify: bool = True
                    ) -> Tuple[PipelineSchedule, RepairReport]:
    """Delta-recompile `artifact` for ``transform.apply(artifact.topo)``.

    The result is byte-identical (same canonical serialization) to cold-
    compiling the degraded topology with the same kind/P/root, and is
    re-verified on the degraded graph (`verify=True` replays every chunk
    through the simulator's correctness checker; disabling it skips only
    the replay, never the exactness postconditions).

    Repair assumes the artifact was compiled with the automatic k (the
    §2.4 fixed-k floor is not recorded on artifacts and its floor-scaled
    capacities do not delta-compose); fixed-k artifacts must be recompiled
    cold.
    """
    from . import plan as plan_mod

    t0 = time.perf_counter()
    spec = _transform_of(transform)
    if artifact.kind not in plan_mod.PLAN_KINDS:
        raise RepairError(f"cannot repair artifact kind {artifact.kind!r}")
    if artifact.kind == "alltoall":
        raise RepairError(
            "cannot repair alltoall artifacts: the merged per-source "
            "scatter rounds are rebuilt whole-cloth from the packing, so a "
            "delta-recompile saves nothing over compiling the degraded "
            "topology cold — recompile instead")
    base_topo = artifact.topo
    try:
        degraded = spec.apply(base_topo)
    except ValueError as e:
        raise RepairError(f"{spec} does not apply to "
                          f"{base_topo.name}: {e}") from e
    rooted = artifact.kind in plan_mod._ROOTED
    plan = plan_mod.plan_for(
        artifact.kind, degraded, num_chunks=artifact.num_chunks,
        root=artifact.root if rooted else None)

    warm_solve = warm_split = False
    solve_rounds = 0
    base_work = base_topo.transpose() if plan.is_dual else base_topo
    if rooted:
        # Appendix-A λ(root) is a cheap direct computation; run stage 1 as-is
        plan = plan_mod.solve(plan)
    else:
        base_net = WARM.solve_net(base_work.fingerprint())
        net = None
        if base_net is not None:
            net = base_net.clone(g=plan.work)
            warm_solve = True
        c0 = COUNTERS.snapshot()
        ts = time.perf_counter()
        opt, solve_rounds = _repair_optimality(plan.work, artifact.opt, net)
        wall = time.perf_counter() - ts
        scaled = plan.work.scaled(opt.U)
        plan = dataclasses.replace(
            plan, opt=opt, scaled=scaled,
            stats=plan.stats.with_stage(
                "solve", wall, k=opt.k, U=str(opt.U),
                inv_x_star=str(opt.inv_x_star), repair="dinkelbach",
                rounds=solve_rounds, warm=warm_solve,
                **COUNTERS.delta(c0)))
        if net is not None:
            WARM.offer_solve(plan.work, net)

    # stage 2: transplant the base split prober when one is retained
    g = plan.scaled
    switched = g.switches and any(w in e for e in g.cap for w in g.switches)
    factory = None
    if switched:
        # Transplant only when the degraded optimum *matches* the base one:
        # then the scaled graphs differ solely on the transformed link and
        # every retained flow re-validates after a single-edge delta.  A
        # changed (U, k) / λ rescales every capacity and demand, and
        # draining the base flows down to the new limits costs more than a
        # cold run — fall back to the cold oracle (exact either way; this
        # gate is purely about speed).
        if rooted:
            if plan.opt.k == artifact.opt.k:
                base_scaled_fp = base_work.fingerprint()  # rooted: U = 1
                entry = WARM.split_prober(
                    base_scaled_fp, "rooted", (artifact.root, artifact.opt.k))
                if entry is not None:
                    demands = {plan.root: plan.opt.k}
                    factory = (lambda dd: _replay_or_raw(
                        _RootedProber.transplant(
                            getattr(entry, "inner", entry), dd, demands),
                        dd, entry))
        elif (plan.opt.U, plan.opt.k) == (artifact.opt.U, artifact.opt.k):
            base_scaled_fp = base_work.scaled(artifact.opt.U).fingerprint()
            entry = WARM.split_prober(
                base_scaled_fp, "tree", artifact.opt.k)
            if entry is not None:
                k2 = plan.opt.k
                factory = (lambda dd: _replay_or_raw(
                    _TheoremEightProber.transplant(
                        getattr(entry, "inner", entry), dd, k2),
                    dd, entry))
        warm_split = factory is not None
    plan = plan_mod.split(plan, prober_factory=factory)

    plan = plan_mod.rounds(plan_mod.pack(plan))
    art = plan_mod.emit(plan)

    # re-verify: replay the repaired schedule on the degraded graph
    from . import simulate as sim
    fn = {"allgather": sim.simulate_allgather,
          "reduce_scatter": sim.simulate_reduce_scatter,
          "broadcast": sim.simulate_broadcast,
          "reduce": sim.simulate_reduce}[art.kind]
    try:
        rep = fn(art, verify=verify)
    except Exception as e:
        raise RepairError(
            f"repaired {art.kind} schedule failed verification on "
            f"{degraded.name}: {e}") from e
    art.claimed_runtime = rep.sim_time

    report = RepairReport(
        kind=artifact.kind, transform=str(spec),
        base_topology=base_topo.name, degraded_topology=degraded.name,
        repair_time_s=time.perf_counter() - t0,
        warm_solve=warm_solve, warm_split=warm_split,
        solve_rounds=solve_rounds, verified=verify,
        claimed_runtime=str(rep.sim_time))
    return art, report


def repair_artifact(artifact: Union[PipelineSchedule, AllReduceSchedule],
                    transform, verify: bool = True):
    """Repair a cached artifact for a topology transform.  Allreduce
    artifacts repair both halves (reduce-scatter + allgather) and
    recompose; the merged report sums the halves' wall time."""
    if isinstance(artifact, AllReduceSchedule):
        rs, rep_rs = repair_schedule(artifact.rs, transform, verify=verify)
        ag, rep_ag = repair_schedule(artifact.ag, transform, verify=verify)
        report = RepairReport(
            kind="allreduce", transform=rep_rs.transform,
            base_topology=rep_rs.base_topology,
            degraded_topology=rep_rs.degraded_topology,
            repair_time_s=rep_rs.repair_time_s + rep_ag.repair_time_s,
            warm_solve=rep_rs.warm_solve and rep_ag.warm_solve,
            warm_split=rep_rs.warm_split and rep_ag.warm_split,
            solve_rounds=rep_rs.solve_rounds + rep_ag.solve_rounds,
            verified=verify,
            claimed_runtime=str(Fraction(rep_rs.claimed_runtime) +
                                Fraction(rep_ag.claimed_runtime)))
        return AllReduceSchedule(rs=rs, ag=ag), report
    return repair_schedule(artifact, transform, verify=verify)
