"""Dinic's max-flow — the oracle engine behind every theorem in the paper.

Dinic's algorithm is strongly polynomial (O(V^2 E) independent of capacity
values), which is what makes the whole schedule generator strongly
polynomial.  We add an optional `limit` argument: every caller in this
codebase only ever needs to know whether the flow reaches some threshold
(Theorems 1, 5, 8, 12), so we stop augmenting as soon as the threshold is
met — a large constant-factor win.

Two substrates back the same `FlowNetwork` API:

* a pure-Python Dinic over adjacency linked lists — the reference-shaped
  slow path, used for small networks (where interpreter overhead beats
  array set-up costs), and whenever capacities leave the int64 range
  (capacities are Python ints, arbitrary precision: the optimality search
  scales capacities by binary-search denominators);
* a compact array substrate: capacities live in a numpy int64 array and
  probes on large networks are solved by `scipy.sparse.csgraph.maximum_flow`
  (a compiled Dinic) over a cached CSR view of the network.  The CSR
  structure (coalesced coordinates, group index, residual write-back
  permutations) is built once per network shape and only capacity *data*
  moves per probe.  An extra bottleneck node `b` with a single `b -> s`
  edge of capacity `limit` realises the exact early-exit semantics
  (`min(F, limit)`) without giving up the compiled inner loops.

Both substrates return exact flow values, so every oracle verdict — and
therefore every emitted schedule byte — is independent of which one ran.
The differential suite (`repro.core.reference`,
`tests/test_reference_differential.py`) pins this equivalence.

Reuse: every binary search in the compiler probes the *same* network shape
with different capacities, and every Theorem-5-style oracle sweeps the same
network over all sinks.  `FlowNetwork.set_edge_cap` + `reset_flow` make one
network serve a whole search, and `SourcedNetwork` packages the recurring
"graph + super-source + rewritable capacities" pattern — one allocation per
search instead of O(|Vc| · log C) fresh builds.

Incremental engine (warm starts): `increase_edge_cap` / `decrease_edge_cap`
rewrite a capacity while keeping the current flow *feasible* — an increase
leaves the flow untouched (later probes only augment the delta), a decrease
drains the excess along residual paths (reroute first, then cancel back to
the source/sink) instead of resetting the whole network.  On top of that,
`SourcedNetwork.min_source_flow_at_least` keeps a per-sink flow snapshot
(`warm=True`) so the monotone binary searches of §2.2 re-augment small
capacity deltas instead of recomputing each sink's flow from zero, and it
adaptively reorders sinks (last-failing sink first) so infeasible probes
fail after one maxflow instead of |Vc|.  Neither changes any oracle
verdict: maxflow values are exact, and the sweep is a pure conjunction.
"""
from __future__ import annotations

from fractions import Fraction
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .graph import DiGraph, Edge

try:
    from scipy.sparse import csr_matrix
    from scipy.sparse.csgraph import maximum_flow as _scipy_maxflow
    HAVE_SCIPY = True
except Exception:  # pragma: no cover — scipy is part of the baked image
    HAVE_SCIPY = False

INF = float("inf")

#: networks with fewer residual-edge entries than this stay on the Python
#: substrate: one scipy probe costs ~0.5ms of fixed wrapper/validation
#: work, which swamps a Dinic run on a tiny network.  Tuned on the zoo
#: (fattree[8p4l2h] pack probes sit just above it, small fixture probes
#: well below).  Tests monkeypatch this to 0 to force the array substrate
#: onto small fixtures.
FAST_MIN_ENTRIES = 384

#: total capacity at or above this bails to the Python substrate: scipy's
#: maximum_flow silently casts capacities to int32, so every entry *and*
#: the flow value must stay below 2^31.  Guarding the capacity sum covers
#: both (each entry and the achievable flow are bounded by the total).
_FAST_CAP_LIMIT = (1 << 31) - 1


class OracleCounters:
    """Per-process maxflow instrumentation: `probes` counts `maxflow`
    invocations (including warm-start drains/reroutes), `augments` counts
    augmenting paths pushed by the Python substrate (the scipy substrate
    does not expose its augmentation count; large-network probes therefore
    contribute probes but no augments).  The staged compiler snapshots the
    global `COUNTERS` around each stage and records the deltas in its stage
    meta (they surface in BENCH rows as ``oracle_probes`` /
    ``oracle_augments``)."""

    __slots__ = ("probes", "augments")

    def __init__(self) -> None:
        self.probes = 0
        self.augments = 0

    def snapshot(self) -> Tuple[int, int]:
        return (self.probes, self.augments)

    def delta(self, snap: Tuple[int, int]) -> Dict[str, int]:
        return {"probes": self.probes - snap[0],
                "augments": self.augments - snap[1]}


COUNTERS = OracleCounters()


def _store(arr: np.ndarray, idx: int, val: int) -> np.ndarray:
    """Scalar store into a capacity array, promoting to an object-dtype
    array (arbitrary-precision Python ints) when `val` leaves int64."""
    try:
        arr[idx] = val
        return arr
    except OverflowError:
        arr = arr.astype(object)
        arr[idx] = val
        return arr


def _int_array(vals: Iterable[int]) -> np.ndarray:
    """int64 array of `vals`, or object dtype when a value doesn't fit."""
    vals = list(vals)
    try:
        return np.array(vals, dtype=np.int64)
    except OverflowError:
        return np.array(vals, dtype=object)


def _cap_block(caps: Sequence[int]) -> np.ndarray:
    """Interleave `caps` with their zero reverse capacities, as int64 when
    the values fit and object dtype otherwise."""
    try:
        block = np.zeros(2 * len(caps), dtype=np.int64)
        block[0::2] = caps
        return block
    except OverflowError:
        block = np.zeros(2 * len(caps), dtype=object)
        block[0::2] = caps
        return block


def _concat_caps(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    if a.dtype == object or b.dtype == object:
        return np.concatenate([a.astype(object), b.astype(object)])
    return np.concatenate([a, b])


class _CsrSolver:
    """Cached CSR structure for one `FlowNetwork` shape, solved by scipy's
    compiled Dinic.

    Entries 0..m-1 mirror the network's residual-edge entries (entry i is
    the directed coordinate ``to[i^1] -> to[i]``); entries m..m+2n-1 are
    the bottleneck gadget: a virtual node ``b = n`` with a coordinate pair
    ``b <-> u`` for every node u.  Per probe only the data vector changes:
    real entries carry the current residual capacities and the single
    ``b -> s`` entry carries the probe's `limit` (the whole flow must cross
    it, so the solve returns exactly ``min(F(s, t), limit)`` — the same
    early-exit contract as the Python substrate).

    Parallel entries of one coordinate are coalesced for the solve and the
    resulting net coordinate flow is distributed back to the entries
    greedily in edge-id order (a segmented prefix-sum), yielding a valid
    residual state with the exact flow value.  Which parallel entry carries
    the flow is not observable: every caller consumes flow *values* (and
    the canonical min-cut side, which is distribution-independent)."""

    __slots__ = ("m", "n", "order", "gid_sorted", "starts", "partner",
                 "indices", "indptr", "checked")

    def __init__(self, net: "FlowNetwork"):
        m, n = len(net.to), net.n
        self.m, self.n = m, n
        t = np.asarray(net.to, dtype=np.int64)
        rows = np.empty(m + 2 * n, dtype=np.int64)
        cols = np.empty(m + 2 * n, dtype=np.int64)
        rows[0:m:2] = t[1::2]
        rows[1:m:2] = t[0::2]
        cols[:m] = t
        ar = np.arange(n, dtype=np.int64)
        rows[m:m + n] = n
        cols[m:m + n] = ar
        rows[m + n:] = ar
        cols[m + n:] = n
        partner = np.empty(m + 2 * n, dtype=np.int64)
        partner[:m] = np.arange(m, dtype=np.int64) ^ 1
        partner[m:m + n] = ar + m + n
        partner[m + n:] = ar + m
        order = np.lexsort((cols, rows))
        r_s, c_s = rows[order], cols[order]
        newgrp = np.empty(len(order), dtype=bool)
        newgrp[0] = True
        newgrp[1:] = (r_s[1:] != r_s[:-1]) | (c_s[1:] != c_s[:-1])
        self.order = order
        self.gid_sorted = np.cumsum(newgrp) - 1
        self.starts = np.flatnonzero(newgrp)
        self.partner = partner
        urows = r_s[self.starts]
        counts = np.bincount(urows, minlength=n + 1)
        self.indptr = np.concatenate(
            ([0], np.cumsum(counts))).astype(np.int32)
        self.indices = c_s[self.starts].astype(np.int32)
        self.checked = False

    def solve(self, net: "FlowNetwork", s: int, t: int,
              limit: Optional[int]) -> Optional[int]:
        """min(F(s, t), limit) on `net`'s current residual capacities, or
        None when the capacities are too large for scipy's int32 core (the
        caller falls back to the exact Python substrate)."""
        m, n = self.m, self.n
        cap = net.cap
        # max-check first: it bounds the int64 sum below any wrap, and a
        # single over-limit entry already forces the fallback
        if len(cap) and int(cap.max()) >= _FAST_CAP_LIMIT:
            return None
        total = int(cap.sum())
        if total >= _FAST_CAP_LIMIT:
            return None
        ec = np.zeros(m + 2 * n, dtype=np.int64)
        ec[:m] = cap
        lim = total + 1 if limit is None else min(int(limit), total + 1)
        if lim <= 0:
            return 0
        ec[m + s] = lim
        ec_s = ec[self.order]
        # int32 data: scipy's core is int32 (the _FAST_CAP_LIMIT guard
        # above makes the cast exact) and handing it pre-cast data skips a
        # full-matrix astype copy inside the wrapper.
        agg = np.add.reduceat(ec_s, self.starts).astype(np.int32)
        mat = csr_matrix((agg, self.indices, self.indptr),
                         shape=(n + 1, n + 1))
        res = _scipy_maxflow(mat, n, t)
        flow = res.flow
        if not self.checked:
            # scipy preserves the input structure when every coordinate's
            # reverse is present (ours always is: entries come in pairs)
            if (len(flow.data) != len(agg)
                    or not np.array_equal(flow.indices, self.indices)):
                raise RuntimeError("scipy flow structure mismatch")
            self.checked = True
        fpos = np.maximum(flow.data, 0).astype(np.int64)
        if fpos.any():
            cs = np.cumsum(ec_s)
            base = np.concatenate(
                ([0], cs[self.starts[1:] - 1]))[self.gid_sorted]
            take_s = np.clip(fpos[self.gid_sorted] - (cs - ec_s - base),
                             0, ec_s)
            take = np.empty_like(take_s)
            take[self.order] = take_s
            new_ec = ec - take + take[self.partner]
            cap[:] = new_ec[:m]
        return int(res.flow_value)


class FlowNetwork:
    """Residual flow network with integer capacities.

    Capacities live in a numpy array (`int64`, promoted to object dtype if
    a capacity ever leaves the int64 range).  The adjacency linked lists
    only serve the Python substrate and `min_cut_side`; they are built
    lazily (`_ensure_adj`) so bulk builders that stay on the array
    substrate never pay for them."""

    __slots__ = ("n", "to", "cap", "head", "nxt", "_adj_m", "_fast")

    def __init__(self, n: int):
        self.n = n
        # edge arrays (paired: edge i and i^1 are residual partners)
        self.to: List[int] = []
        self.cap: np.ndarray = np.zeros(0, dtype=np.int64)
        # adjacency as linked lists: head[u] -> edge index, nxt[i] -> next
        # edge; valid for the first `_adj_m` entries of `to`
        self.head: List[int] = [-1] * n
        self.nxt: List[int] = []
        self._adj_m = 0
        self._fast: Optional[_CsrSolver] = None

    def add_node(self) -> int:
        self.head.append(-1)
        self.n += 1
        return self.n - 1

    def add_edge(self, u: int, v: int, cap: int) -> int:
        """Add directed edge u->v with given capacity; returns edge id."""
        i = len(self.to)
        self.to.append(v)
        self.to.append(u)
        if self._adj_m == i:      # adjacency current: extend incrementally
            self.nxt.append(self.head[u]); self.head[u] = i
            self.nxt.append(self.head[v]); self.head[v] = i + 1
            self._adj_m = i + 2
        self.cap = _concat_caps(self.cap, _cap_block([cap]))
        return i

    def add_edges(self, edges: Iterable[Tuple[int, int, int]]) -> None:
        """Bulk `add_edge` for the hot network builders — same layout, one
        array concatenation instead of one append per edge.  Edge ids are
        assigned in order (first edge gets id len(to) before the call,
        then +2 per edge)."""
        edges = list(edges)
        if not edges:
            return
        to = self.to
        for u, v, _ in edges:
            to.append(v)
            to.append(u)
        self.cap = _concat_caps(self.cap, _cap_block([c for _, _, c in edges]))

    def _ensure_adj(self) -> None:
        """(Re)build the adjacency linked lists from `to`.  Insertion order
        matches per-edge construction exactly, so the Python substrate
        traverses identically however the edges were added."""
        to = self.to
        if self._adj_m == len(to):
            return
        head = [-1] * self.n
        nxt = [0] * len(to)
        for i in range(len(to)):
            u = to[i ^ 1]
            nxt[i] = head[u]
            head[u] = i
        self.head, self.nxt, self._adj_m = head, nxt, len(to)

    def edge_flow(self, edge_id: int) -> int:
        """Flow currently pushed through edge `edge_id` (reverse residual)."""
        return int(self.cap[edge_id ^ 1])

    def clone(self) -> "FlowNetwork":
        """Independent copy (arrays duplicated) — the transplant primitive:
        a repair run copies a retained oracle network and rewrites its
        capacities instead of rebuilding the layout."""
        dup = FlowNetwork(0)
        dup.n = self.n
        dup.to = list(self.to)
        dup.cap = self.cap.copy()
        dup.head = list(self.head)
        dup.nxt = list(self.nxt)
        dup._adj_m = self._adj_m
        dup._fast = self._fast    # structure is shape-keyed and immutable
        return dup

    def set_edge_cap(self, edge_id: int, cap: int) -> None:
        """Rewrite edge `edge_id`'s capacity in place (clearing any flow on
        it) — the probe primitive that lets one network serve a whole
        binary search instead of being rebuilt per probe."""
        self.cap = _store(self.cap, edge_id, cap)
        self.cap[edge_id ^ 1] = 0

    def reset_flow(self) -> None:
        cap = self.cap
        cap[0::2] += cap[1::2]
        cap[1::2] = 0

    # -- flow-preserving capacity updates (the warm-start primitives) --- #

    def increase_edge_cap(self, edge_id: int, new_cap: int) -> None:
        """Raise edge `edge_id`'s capacity to `new_cap` without touching the
        flow currently on it: the flow stays feasible and a later `maxflow`
        call only augments the delta."""
        flow = int(self.cap[edge_id ^ 1])
        if new_cap < flow:
            raise ValueError(f"increase_edge_cap to {new_cap} below current "
                             f"flow {flow} on edge {edge_id}")
        self.cap = _store(self.cap, edge_id, new_cap - flow)

    def decrease_edge_cap(self, edge_id: int, new_cap: int,
                          s: int, t: int) -> int:
        """Lower edge `edge_id`'s capacity to `new_cap`, draining any excess
        flow along residual paths instead of resetting the network.

        Excess is first *rerouted* (an equal amount of u->v flow found in
        the residual graph, preserving the s->t flow value; this also
        cancels any cycle-borne flow through the edge) and what cannot be
        rerouted is *cancelled* back along the paths that carried it
        (u⇝s and t⇝v residual pushes, which always exist by flow
        decomposition).  Returns the s->t flow value lost, so a caller
        tracking the current flow value can subtract it."""
        flow = int(self.cap[edge_id ^ 1])
        if flow <= new_cap:
            self.cap = _store(self.cap, edge_id, new_cap - flow)
            return 0
        excess = flow - new_cap
        self.cap[edge_id] = 0
        self.cap = _store(self.cap, edge_id ^ 1, new_cap)
        u, v = self.to[edge_id ^ 1], self.to[edge_id]
        short = excess - self.maxflow(u, v, limit=excess)
        if short:
            if u != s:
                got = self.maxflow(u, s, limit=short)
                if got != short:  # pragma: no cover — invariant violation
                    raise RuntimeError(
                        f"drain failed: cancelled {got}/{short} at node {u}")
            if v != t:
                got = self.maxflow(t, v, limit=short)
                if got != short:  # pragma: no cover — invariant violation
                    raise RuntimeError(
                        f"drain failed: restored {got}/{short} at node {v}")
        return short

    # ------------------------------------------------------------------ #
    def maxflow(self, s: int, t: int, limit: Optional[int] = None) -> int:
        """Max flow s->t, early-exiting once `limit` is reached (the
        returned value is exactly ``min(F, limit)`` on both substrates)."""
        if s == t:
            raise ValueError("source == sink")
        COUNTERS.probes += 1
        if (HAVE_SCIPY and len(self.to) >= FAST_MIN_ENTRIES
                and self.cap.dtype != object):
            fast = self._fast
            if fast is None or fast.m != len(self.to) or fast.n != self.n:
                fast = self._fast = _CsrSolver(self)
            value = fast.solve(self, s, t, limit)
            if value is not None:
                return value
        return self._maxflow_py(s, t, limit)

    def _maxflow_py(self, s: int, t: int, limit: Optional[int]) -> int:
        """The pure-Python Dinic substrate (reference-shaped; also the
        arbitrary-precision and small-network path).  Runs on a plain-list
        copy of the capacities — interpreter loops over lists beat numpy
        scalar indexing — and writes the residual state back."""
        self._ensure_adj()
        flow = 0
        cap = self.cap.tolist()
        to, nxt, head = self.to, self.nxt, self.head
        while limit is None or flow < limit:
            # BFS level graph, pruned at the sink's level (nodes further
            # out can never lie on a shortest augmenting path)
            level = [-1] * self.n
            level[s] = 0
            queue = [s]
            qi = 0
            tlevel = self.n
            while qi < len(queue):
                u = queue[qi]; qi += 1
                if level[u] >= tlevel:
                    continue
                i = head[u]
                while i != -1:
                    v = to[i]
                    if cap[i] > 0 and level[v] < 0:
                        level[v] = level[u] + 1
                        if v == t:
                            tlevel = level[v]
                        queue.append(v)
                    i = nxt[i]
            if level[t] < 0:
                break
            # iterative DFS blocking flow with current-arc optimisation
            it = list(head)
            while True:
                # find augmenting path in level graph
                path: List[int] = []  # edge ids
                u = s
                found = False
                while True:
                    if u == t:
                        found = True
                        break
                    i = it[u]
                    advanced = False
                    while i != -1:
                        v = to[i]
                        if cap[i] > 0 and level[v] == level[u] + 1:
                            path.append(i)
                            u = v
                            advanced = True
                            break
                        i = nxt[i]
                        it[u] = i
                    if not advanced:
                        if not path:
                            break
                        # retreat: dead-end, remove node from level graph
                        level[u] = -1
                        last = path.pop()
                        u = to[last ^ 1]
                        it[u] = nxt[last] if it[u] == last else it[u]
                if not found:
                    break
                COUNTERS.augments += 1
                aug = min(cap[i] for i in path)
                if limit is not None:
                    aug = min(aug, limit - flow)
                for i in path:
                    cap[i] -= aug
                    cap[i ^ 1] += aug
                flow += aug
                if limit is not None and flow >= limit:
                    break
            if limit is not None and flow >= limit:
                break
        self.cap[:] = cap
        return flow

    def min_cut_side(self, s: int) -> List[int]:
        """After maxflow, the source side of a min cut (residual-reachable).
        For a *maximum* flow this set is canonical (the unique minimal
        source side), independent of which substrate found the flow."""
        self._ensure_adj()
        seen = [False] * self.n
        seen[s] = True
        stack = [s]
        while stack:
            u = stack.pop()
            i = self.head[u]
            while i != -1:
                v = self.to[i]
                if self.cap[i] > 0 and not seen[v]:
                    seen[v] = True
                    stack.append(v)
                i = self.nxt[i]
        return [u for u in range(self.n) if seen[u]]


def warm_restore(net: FlowNetwork, cur_tgt: np.ndarray,
                 state: Tuple[np.ndarray, int, np.ndarray],
                 src: int, snk: int, limit: int) -> int:
    """Restore a flow snapshot taken for (src, snk), apply the capacity
    deltas accumulated since (flow-preserving increase/decrease against the
    target-capacity records), and re-augment up to `limit`.

    `state` is `(cap snapshot, flow value, target snapshot)`; `cur_tgt` is
    the *current* per-edge target capacities (index = edge id >> 1).  The
    snapshot must be a valid conserving src->snk flow; the result is an
    exact maxflow value capped at `limit` — it may exceed `limit` when the
    restored flow already did, which callers treat identically (every user
    only compares against, or clamps at, the limit).  This is the delta
    engine behind the per-sink `warm=True` sweeps, the keyed `warm_flow`
    store, and the §2.3 gadget warm probes."""
    caps, value, tgt = state
    cap = net.cap
    m0 = len(tgt)
    cap[:len(caps)] = caps
    # edges added since the snapshot carried no flow: install fresh
    if len(cur_tgt) > m0:
        cap[2 * m0::2] = cur_tgt[m0:]
        cap[2 * m0 + 1::2] = 0
    decreases: List[Tuple[int, int]] = []
    for j in np.flatnonzero(cur_tgt[:m0] != tgt).tolist():
        new = int(cur_tgt[j])
        if new > tgt[j]:     # increases first: more reroute room
            net.increase_edge_cap(2 * j, new)
        else:
            decreases.append((2 * j, new))
    for eid, new in decreases:
        value -= net.decrease_edge_cap(eid, new, src, snk)
    if value < limit:
        value += net.maxflow(src, snk, limit=limit - value)
    return value


# ---------------------------------------------------------------------- #
# Reusable oracle network
# ---------------------------------------------------------------------- #

class SourcedNetwork:
    """A `FlowNetwork` over a `DiGraph` plus a super-source, built **once**
    per search and re-probed in place.

    Every graph edge's id is recorded so callers can rewrite capacities
    between probes (`set_cap` / `rescale_graph_caps` / `floor_graph_caps`)
    and the flow is cleared between sinks with `reset_flow` — replacing the
    O(|Vc| · log C) fresh `FlowNetwork` builds the binary-search oracles
    used to pay for.  `extra` edges (the Theorem-8 ∞ gadget edges) are
    installed at construction; per-sink gadget edges are added with
    `add_probe_edge` at capacity 0 and toggled with `set_cap_id` — a
    zero-capacity edge never carries flow, so inactive gadget edges are
    invisible to the oracle.

    The network tracks a *target capacity* per edge (`_tgt`), which is what
    makes warm starts possible: `min_source_flow_at_least(..., warm=True)`
    snapshots each sink's flow state after its probe and, on the next probe
    of the same sink, restores the snapshot and applies only the capacity
    deltas (flow-preserving `increase_edge_cap` / `decrease_edge_cap`)
    before re-augmenting — the §2.2 binary searches touch 2-3 edges per
    probe, so re-augmenting the delta replaces a full recompute.  The sweep
    also remembers the last failing sink (move-to-front), so infeasible
    probes usually fail on the first maxflow.
    """

    __slots__ = ("g", "net", "s", "eid", "src_eid", "_tgt", "_order",
                 "_warm", "last_failing")

    def __init__(self, g: DiGraph,
                 source_caps: Optional[Mapping[int, int]] = None,
                 extra: Sequence[Tuple[int, int, int]] = ()):
        self.g = g
        self.net = FlowNetwork(g.num_nodes + 1)
        self.s = g.num_nodes
        self.eid = {e: 2 * i for i, e in enumerate(g.cap)}
        self.net.add_edges((u, v, c) for (u, v), c in g.cap.items())
        self.src_eid: Dict[int, int] = {}
        for u, m in sorted((source_caps or {}).items()):
            self.src_eid[u] = self.net.add_edge(self.s, u, m)
        for (a, b, c) in extra:
            self.net.add_edge(a, b, c)
        self._tgt: np.ndarray = self.net.cap[0::2].copy()
        self._order: Optional[List[int]] = None    # adaptive sink order
        # sink -> (cap snapshot, flow value, target snapshot)
        self._warm: Dict[int, Tuple[np.ndarray, int, np.ndarray]] = {}
        self.last_failing: Optional[int] = None    # sink of last failed sweep

    def clone(self, g: Optional[DiGraph] = None) -> "SourcedNetwork":
        """Independent copy for transplanting a retained oracle onto a
        repaired compile.  Passing `g` rebinds the graph the capacity
        rewrites read from (`rescale_graph_caps` / `floor_graph_caps` use
        `self.g.cap.get(e, 0)` over the recorded edge ids, so a clone bound
        to a degraded graph probes the degraded capacities — edges the new
        graph lacks become capacity 0, which is invisible to the oracle)."""
        dup = object.__new__(SourcedNetwork)
        dup.g = self.g if g is None else g
        dup.net = self.net.clone()
        dup.s = self.s
        dup.eid = dict(self.eid)
        dup.src_eid = dict(self.src_eid)
        dup._tgt = self._tgt.copy()
        dup._order = None if self._order is None else list(self._order)
        # snapshot tuples are never mutated in place (warm probes replace
        # entries wholesale), so sharing them with the source is safe
        dup._warm = dict(self._warm)
        dup.last_failing = self.last_failing
        return dup

    def ensure_edge(self, u: int, v: int) -> int:
        """Edge id of (u, v), adding a capacity-0 edge if absent (probes of
        edge-splitting moves may create logical edges the graph lacks)."""
        e = (u, v)
        if e not in self.eid:
            self.eid[e] = self.net.add_edge(u, v, 0)
            self._tgt = np.append(self._tgt, 0)
        return self.eid[e]

    def add_probe_edge(self, u: int, v: int) -> int:
        """An initially-inactive (capacity 0) gadget edge — always parallel
        to (never merged with) any graph edge (u, v), toggled per probe
        with `set_cap_id`."""
        eid = self.net.add_edge(u, v, 0)
        self._tgt = np.append(self._tgt, 0)
        return eid

    # -- capacity rewrites between probes ------------------------------- #

    def set_cap_id(self, edge_id: int, cap: int) -> None:
        """Rewrite one edge's capacity by id, keeping the target-capacity
        record coherent (all capacity writes must go through here or
        `set_cap`, or warm starts would diff against a stale target)."""
        self.net.set_edge_cap(edge_id, cap)
        self._tgt = _store(self._tgt, edge_id >> 1, cap)

    def set_cap(self, u: int, v: int, cap: int) -> None:
        self.set_cap_id(self.ensure_edge(u, v), cap)

    def increase_cap_id(self, edge_id: int, cap: int) -> None:
        """Flow-preserving capacity increase by id (target kept coherent)."""
        self.net.increase_edge_cap(edge_id, cap)
        self._tgt = _store(self._tgt, edge_id >> 1, cap)

    def decrease_cap_id(self, edge_id: int, cap: int,
                        source: int, sink: int) -> int:
        """Flow-preserving capacity decrease by id: drains excess flow along
        residual paths of the current source->sink flow; returns the flow
        value lost."""
        lost = self.net.decrease_edge_cap(edge_id, cap, source, sink)
        self._tgt = _store(self._tgt, edge_id >> 1, cap)
        return lost

    def rescale_graph_caps(self, scale: int) -> None:
        """caps := b_e * scale for every graph edge (Theorem-1 probes)."""
        cap = self.g.cap
        for e, i in self.eid.items():
            self.set_cap_id(i, cap.get(e, 0) * scale)

    def floor_graph_caps(self, factor: Fraction) -> None:
        """caps := ⌊factor * b_e⌋ for every graph edge (§2.4 probes)."""
        cap = self.g.cap
        for e, i in self.eid.items():
            self.set_cap_id(i, int(factor * cap.get(e, 0)))

    def set_source_caps(self, cap: int) -> None:
        for i in self.src_eid.values():
            self.set_cap_id(i, cap)

    # -- oracle sweeps --------------------------------------------------- #

    def _ordered(self, sinks: Sequence[int]) -> List[int]:
        """`sinks` reordered by the adaptive history: previously-failing
        sinks first (move-to-front), new sinks appended in given order."""
        if self._order is None:
            self._order = list(sinks)
            return self._order
        ss = set(sinks)
        order = [v for v in self._order if v in ss]
        seen = set(order)
        order += [v for v in sinks if v not in seen]
        self._order = order
        return order

    def min_source_flow_at_least(self, sinks: Iterable[int], threshold: int,
                                 warm: bool = False) -> bool:
        """min_{v ∈ sinks} F(s, v) >= threshold, early-exiting per sink and
        on first failure (the Theorem-1/5 oracle shape).

        The sink order adapts across calls (last-failing sink first); the
        verdict is order-independent (a pure conjunction of exact per-sink
        oracles).  With `warm=True` each sink keeps a flow snapshot reused
        by its next probe — only valid while capacity changes between
        probes go through the `set_cap*` family."""
        net, s = self.net, self.s
        order = self._ordered(list(sinks))
        for idx, v in enumerate(order):
            if warm:
                f = self._warm_probe(v, threshold)
            else:
                net.reset_flow()
                f = net.maxflow(s, v, limit=threshold)
            if f < threshold:
                if idx:      # move the failing sink to the front
                    order.remove(v)
                    order.insert(0, v)
                self.last_failing = v
                return False
        self.last_failing = None
        return True

    def _warm_value(self, state: Tuple[np.ndarray, int, np.ndarray],
                    src: int, snk: int, limit: int) -> int:
        return warm_restore(self.net, self._tgt, state, src, snk, limit)

    def _warm_probe(self, v: int, threshold: int) -> int:
        """F(s, v) >= threshold probe warm-started from v's last flow."""
        net, s = self.net, self.s
        state = self._warm.get(v)
        if state is None:
            net.reset_flow()
            value = net.maxflow(s, v, limit=threshold)
        else:
            value = self._warm_value(state, s, v, threshold)
        self._warm[v] = (net.cap.copy(), value, self._tgt.copy())
        return value

    def warm_flow(self, store: Dict, key, src: int, snk: int, limit: int,
                  maxsize: int = 512) -> int:
        """Maxflow src->snk warm-started from `store[key]` (a snapshot a
        previous call with the same key left behind); falls back to a cold
        reset+maxflow when the key is unseen.  The resulting state is
        snapshotted back under `key` (LRU-capped at `maxsize` entries).
        Verdict-exact: the value equals `flow(src, snk, limit)` whenever
        both are < limit, and both are >= limit otherwise."""
        state = store.pop(key, None)
        if state is None:
            self.net.reset_flow()
            value = self.net.maxflow(src, snk, limit=limit)
        else:
            value = self._warm_value(state, src, snk, limit)
        store[key] = (self.net.cap.copy(), value, self._tgt.copy())
        while len(store) > maxsize:
            store.pop(next(iter(store)))
        return value

    def flow(self, a: int, b: int, limit: Optional[int] = None) -> int:
        """One maxflow a->b from a clean (reset) state."""
        self.net.reset_flow()
        return self.net.maxflow(a, b, limit=limit)


# ---------------------------------------------------------------------- #
# Flow-network builders used by the paper's constructions
# ---------------------------------------------------------------------- #

def build_network(g: DiGraph, extra_nodes: int = 0) -> FlowNetwork:
    """FlowNetwork over g's nodes (+extra), with g's edges installed."""
    net = FlowNetwork(g.num_nodes + extra_nodes)
    for (u, v), c in g.cap.items():
        net.add_edge(u, v, c)
    return net


def build_Dk(g: DiGraph, k: int, scale: int = 1) -> Tuple[FlowNetwork, int]:
    """The paper's ``D_k`` network: add source s with cap-k edges to every
    compute node.  Capacities (including k) are multiplied by `scale`
    (used by the rational binary search).  Returns (net, source_id)."""
    net = FlowNetwork(g.num_nodes + 1)
    s = g.num_nodes
    for (u, v), c in g.cap.items():
        net.add_edge(u, v, c * scale)
    for u in sorted(g.compute):
        net.add_edge(s, u, k)  # caller pre-scales k if needed
    return net, s


def min_flow_from_source(g: DiGraph, k_scaled: int, cap_scale: int,
                         threshold: int) -> bool:
    """Test  min_{v∈Vc} F(s, v; G_x)  >=  threshold  (Theorem 1 oracle).

    The rational source capacity x = k_scaled / cap_scale is realised by
    scaling the topology capacities by `cap_scale` and the source edges by
    ... nothing (the caller passes k_scaled already in scaled units).
    """
    for v in sorted(g.compute):
        net, s = build_Dk(g, k_scaled, scale=cap_scale)
        if net.maxflow(s, v, limit=threshold) < threshold:
            return False
    return True
