"""Dinic's max-flow — the oracle engine behind every theorem in the paper.

Dinic's algorithm is strongly polynomial (O(V^2 E) independent of capacity
values), which is what makes the whole schedule generator strongly
polynomial.  We add an optional `limit` argument: every caller in this
codebase only ever needs to know whether the flow reaches some threshold
(Theorems 1, 5, 8, 12), so we stop augmenting as soon as the threshold is
met — a large constant-factor win.

Capacities are Python ints (arbitrary precision): the optimality search
scales capacities by binary-search denominators, which can grow large.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .graph import DiGraph, Edge

INF = float("inf")


class FlowNetwork:
    """Residual flow network with integer capacities."""

    __slots__ = ("n", "to", "cap", "head", "nxt", "first_free")

    def __init__(self, n: int):
        self.n = n
        # edge arrays (paired: edge i and i^1 are residual partners)
        self.to: List[int] = []
        self.cap: List[int] = []
        # adjacency as linked lists: head[u] -> edge index, nxt[i] -> next edge
        self.head: List[int] = [-1] * n
        self.nxt: List[int] = []

    def add_node(self) -> int:
        self.head.append(-1)
        self.n += 1
        return self.n - 1

    def add_edge(self, u: int, v: int, cap: int) -> int:
        """Add directed edge u->v with given capacity; returns edge id."""
        i = len(self.to)
        self.to.append(v); self.cap.append(cap)
        self.nxt.append(self.head[u]); self.head[u] = i
        self.to.append(u); self.cap.append(0)
        self.nxt.append(self.head[v]); self.head[v] = i + 1
        return i

    def edge_flow(self, edge_id: int) -> int:
        """Flow currently pushed through edge `edge_id` (reverse residual)."""
        return self.cap[edge_id ^ 1]

    def reset_flow(self) -> None:
        for i in range(0, len(self.to), 2):
            total = self.cap[i] + self.cap[i + 1]
            self.cap[i] = total
            self.cap[i + 1] = 0

    # ------------------------------------------------------------------ #
    def maxflow(self, s: int, t: int, limit: Optional[int] = None) -> int:
        """Max flow s->t, early-exiting once `limit` is reached."""
        if s == t:
            raise ValueError("source == sink")
        flow = 0
        cap, to, nxt = self.cap, self.to, self.nxt
        while limit is None or flow < limit:
            # BFS level graph
            level = [-1] * self.n
            level[s] = 0
            queue = [s]
            qi = 0
            while qi < len(queue):
                u = queue[qi]; qi += 1
                i = self.head[u]
                while i != -1:
                    v = to[i]
                    if cap[i] > 0 and level[v] < 0:
                        level[v] = level[u] + 1
                        queue.append(v)
                    i = nxt[i]
            if level[t] < 0:
                break
            # iterative DFS blocking flow with current-arc optimisation
            it = list(self.head)
            while True:
                # find augmenting path in level graph
                path: List[int] = []  # edge ids
                u = s
                found = False
                while True:
                    if u == t:
                        found = True
                        break
                    i = it[u]
                    advanced = False
                    while i != -1:
                        v = to[i]
                        if cap[i] > 0 and level[v] == level[u] + 1:
                            path.append(i)
                            u = v
                            advanced = True
                            break
                        i = nxt[i]
                        it[u] = i
                    if not advanced:
                        if not path:
                            break
                        # retreat: dead-end, remove node from level graph
                        level[u] = -1
                        last = path.pop()
                        u = to[last ^ 1]
                        it[u] = nxt[last] if it[u] == last else it[u]
                if not found:
                    break
                aug = min(cap[i] for i in path)
                if limit is not None:
                    aug = min(aug, limit - flow)
                for i in path:
                    cap[i] -= aug
                    cap[i ^ 1] += aug
                flow += aug
                if limit is not None and flow >= limit:
                    return flow
        return flow

    def min_cut_side(self, s: int) -> List[int]:
        """After maxflow, the source side of a min cut (residual-reachable)."""
        seen = [False] * self.n
        seen[s] = True
        stack = [s]
        while stack:
            u = stack.pop()
            i = self.head[u]
            while i != -1:
                v = self.to[i]
                if self.cap[i] > 0 and not seen[v]:
                    seen[v] = True
                    stack.append(v)
                i = self.nxt[i]
        return [u for u in range(self.n) if seen[u]]


# ---------------------------------------------------------------------- #
# Flow-network builders used by the paper's constructions
# ---------------------------------------------------------------------- #

def build_network(g: DiGraph, extra_nodes: int = 0) -> FlowNetwork:
    """FlowNetwork over g's nodes (+extra), with g's edges installed."""
    net = FlowNetwork(g.num_nodes + extra_nodes)
    for (u, v), c in g.cap.items():
        net.add_edge(u, v, c)
    return net


def build_Dk(g: DiGraph, k: int, scale: int = 1) -> Tuple[FlowNetwork, int]:
    """The paper's ``D_k`` network: add source s with cap-k edges to every
    compute node.  Capacities (including k) are multiplied by `scale`
    (used by the rational binary search).  Returns (net, source_id)."""
    net = FlowNetwork(g.num_nodes + 1)
    s = g.num_nodes
    for (u, v), c in g.cap.items():
        net.add_edge(u, v, c * scale)
    for u in sorted(g.compute):
        net.add_edge(s, u, k)  # caller pre-scales k if needed
    return net, s


def min_flow_from_source(g: DiGraph, k_scaled: int, cap_scale: int,
                         threshold: int) -> bool:
    """Test  min_{v∈Vc} F(s, v; G_x)  >=  threshold  (Theorem 1 oracle).

    The rational source capacity x = k_scaled / cap_scale is realised by
    scaling the topology capacities by `cap_scale` and the source edges by
    ... nothing (the caller passes k_scaled already in scaled units).
    """
    for v in sorted(g.compute):
        net, s = build_Dk(g, k_scaled, scale=cap_scale)
        if net.maxflow(s, v, limit=threshold) < threshold:
            return False
    return True
